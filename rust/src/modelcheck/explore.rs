//! Exhaustive breadth-first exploration of a [`Machine`]'s reachable
//! state space, with safety/liveness checking and shortest-trace
//! counterexamples.

use super::machine::{Machine, Violation};
use crate::diagram::dot::Digraph;
use std::collections::HashMap;

/// Bounds and toggles for one exploration run.
#[derive(Clone, Copy, Debug)]
pub struct ExploreConfig {
    /// Abort (as [`CheckFailure::StateLimit`]) past this many distinct
    /// states — the guard against accidentally unbounded scenarios.
    pub max_states: usize,
    /// Treat a terminal non-goal state as a deadlock violation.
    pub check_deadlock: bool,
    /// Require every reachable state to be able to reach a goal state
    /// (eventual-flush liveness under fair scheduling: fairness means no
    /// enabled path is avoided forever, so "a goal stays reachable from
    /// everywhere" is exactly "a fair run eventually gets there").
    pub check_liveness: bool,
    /// Keep the full explored graph in the report for DOT export
    /// (memory-proportional to transitions; meant for small scenarios).
    pub record_graph: bool,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            max_states: 1_000_000,
            check_deadlock: true,
            check_liveness: true,
            record_graph: false,
        }
    }
}

/// A finite action path from the initial state, used to replay a
/// counterexample.
#[derive(Clone, Debug)]
pub struct Trace<M: Machine> {
    /// The machine's initial state.
    pub initial: M::State,
    /// Each step: the action taken and the state it produced.
    pub steps: Vec<(M::Action, M::State)>,
}

impl<M: Machine> Trace<M> {
    /// The final state of the trace.
    pub fn last(&self) -> &M::State {
        self.steps.last().map_or(&self.initial, |(_, s)| s)
    }

    /// Number of actions in the trace.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    /// True when the trace is just the initial state.
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Human-readable replay: one numbered line per step.
    pub fn render(&self, m: &M) -> String {
        let mut out = format!("    0. (init) {}\n", m.state_label(&self.initial));
        for (i, (action, state)) in self.steps.iter().enumerate() {
            out.push_str(&format!(
                "    {}. {} -> {}\n",
                i + 1,
                m.action_label(action),
                m.state_label(state)
            ));
        }
        out
    }
}

/// Why a check failed. Each variant carries a shortest trace to the
/// offending state (BFS discovery order guarantees minimality).
#[derive(Clone, Debug)]
pub enum CheckFailure<M: Machine> {
    /// A state violated the safety invariant.
    Invariant { violation: Violation, trace: Trace<M> },
    /// A transition itself reported a violation; `action` is the step
    /// that failed from the trace's final state.
    Transition { violation: Violation, action: M::Action, trace: Trace<M> },
    /// A terminal state that is not a goal.
    Deadlock { trace: Trace<M> },
    /// A reachable state from which no goal state is reachable.
    Liveness { trace: Trace<M> },
    /// Exploration exceeded [`ExploreConfig::max_states`].
    StateLimit { explored: usize },
}

impl<M: Machine> CheckFailure<M> {
    /// One-line description of the failure kind.
    pub fn headline(&self) -> String {
        match self {
            CheckFailure::Invariant { violation, trace } => {
                format!("invariant violated after {} steps: {violation}", trace.len())
            }
            CheckFailure::Transition { violation, action, trace } => format!(
                "transition {action:?} failed after {} steps: {violation}",
                trace.len()
            ),
            CheckFailure::Deadlock { trace } => {
                format!("deadlock (terminal non-goal state) after {} steps", trace.len())
            }
            CheckFailure::Liveness { trace } => format!(
                "liveness violated: no goal reachable from the state after {} steps",
                trace.len()
            ),
            CheckFailure::StateLimit { explored } => {
                format!("state limit hit after exploring {explored} states")
            }
        }
    }

    /// Full report: headline plus the replayable counterexample trace.
    pub fn render(&self, m: &M) -> String {
        let mut out = self.headline();
        out.push('\n');
        match self {
            CheckFailure::Invariant { trace, .. }
            | CheckFailure::Deadlock { trace }
            | CheckFailure::Liveness { trace } => {
                out.push_str("  shortest counterexample trace:\n");
                out.push_str(&trace.render(m));
            }
            CheckFailure::Transition { action, trace, .. } => {
                out.push_str("  shortest counterexample trace:\n");
                out.push_str(&trace.render(m));
                out.push_str(&format!("    !. {} -> (violation)\n", m.action_label(action)));
            }
            CheckFailure::StateLimit { .. } => {}
        }
        out
    }
}

/// The recorded explored graph (present when
/// [`ExploreConfig::record_graph`] is set).
#[derive(Clone, Debug)]
pub struct Graph<M: Machine> {
    /// Every distinct state, in BFS discovery order.
    pub states: Vec<M::State>,
    /// Every transition as (from, action, to) state indices.
    pub edges: Vec<(u32, M::Action, u32)>,
}

/// Summary of a clean exhaustive exploration.
#[derive(Clone, Debug)]
pub struct Report<M: Machine> {
    /// Distinct states explored.
    pub states: usize,
    /// Transitions taken (including re-entries into known states).
    pub transitions: usize,
    /// Maximum BFS depth (longest shortest-path from the initial state).
    pub depth: usize,
    /// Terminal states (no enabled actions).
    pub terminal: usize,
    /// Goal states.
    pub goals: usize,
    /// The explored graph, when recording was requested.
    pub graph: Option<Graph<M>>,
}

impl<M: Machine> Report<M> {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "explored {} states, {} transitions, depth {}, {} terminal, {} goal",
            self.states, self.transitions, self.depth, self.terminal, self.goals
        )
    }

    /// DOT rendering of the explored graph (needs
    /// [`ExploreConfig::record_graph`]). Goal states are double circles,
    /// the initial state is filled; edges carry action labels.
    pub fn dot(&self, m: &M) -> Option<String> {
        let graph = self.graph.as_ref()?;
        let mut g = Digraph::new("explored");
        g.graph_attr("rankdir", "LR");
        for (i, state) in graph.states.iter().enumerate() {
            let name = format!("s{i}");
            let label = m.state_label(state);
            let mut attrs: Vec<(&str, &str)> = vec![("label", &label)];
            if m.is_goal(state) {
                attrs.push(("shape", "doublecircle"));
            }
            if i == 0 {
                attrs.push(("style", "filled"));
                attrs.push(("fillcolor", "lightgray"));
            }
            g.node(&name, &attrs);
        }
        for &(from, ref action, to) in &graph.edges {
            let label = m.action_label(action);
            g.edge(&format!("s{from}"), &format!("s{to}"), &[("label", &label)]);
        }
        Some(g.finish())
    }
}

/// Exhaustively explore `m` breadth-first from its initial state.
///
/// Checks the safety invariant on every distinct state as it is
/// discovered, propagates transition-reported violations, classifies
/// terminal states (deadlock check), and — after the full graph is known
/// — runs the liveness check by backward reachability from the goal
/// states. Any failure carries a shortest counterexample trace.
#[allow(clippy::type_complexity)]
pub fn explore<M: Machine>(
    m: &M,
    cfg: &ExploreConfig,
) -> Result<Report<M>, Box<CheckFailure<M>>> {
    let initial = m.initial();
    // predecessor links for shortest-trace reconstruction
    let mut preds: Vec<Option<(u32, M::Action)>> = vec![None];
    let mut states: Vec<M::State> = vec![initial.clone()];
    let mut depth: Vec<u32> = vec![0];
    let mut index: HashMap<M::State, u32> = HashMap::new();
    index.insert(initial.clone(), 0);

    let trace_to = |idx: u32, states: &[M::State], preds: &[Option<(u32, M::Action)>]| {
        let mut rev = Vec::new();
        let mut at = idx;
        while let Some((prev, action)) = preds[at as usize].clone() {
            rev.push((action, states[at as usize].clone()));
            at = prev;
        }
        rev.reverse();
        Trace::<M> { initial: states[0].clone(), steps: rev }
    };

    if let Err(violation) = m.invariant(&initial) {
        let trace = Trace::<M> { initial, steps: Vec::new() };
        return Err(Box::new(CheckFailure::Invariant { violation, trace }));
    }

    let mut edges: Vec<(u32, M::Action, u32)> = Vec::new();
    let mut transitions = 0usize;
    let mut terminal = 0usize;
    let mut goals = 0usize;
    if m.is_goal(&initial) {
        goals += 1;
    }
    let mut actions: Vec<M::Action> = Vec::new();

    // `states` doubles as the BFS queue: pushing discoveries to the back
    // while scanning front-to-back is exactly breadth-first order.
    let mut i = 0usize;
    while i < states.len() {
        let state = states[i].clone();
        actions.clear();
        m.actions(&state, &mut actions);
        if actions.is_empty() {
            terminal += 1;
            if cfg.check_deadlock && !m.is_goal(&state) {
                let trace = trace_to(i as u32, &states, &preds);
                return Err(Box::new(CheckFailure::Deadlock { trace }));
            }
        }
        for action in &actions {
            let next = match m.transition(&state, action) {
                Ok(next) => next,
                Err(violation) => {
                    let trace = trace_to(i as u32, &states, &preds);
                    return Err(Box::new(CheckFailure::Transition {
                        violation,
                        action: action.clone(),
                        trace,
                    }));
                }
            };
            transitions += 1;
            let to = match index.get(&next) {
                Some(&id) => id,
                None => {
                    if states.len() >= cfg.max_states {
                        return Err(Box::new(CheckFailure::StateLimit {
                            explored: states.len(),
                        }));
                    }
                    let id = states.len() as u32;
                    index.insert(next.clone(), id);
                    preds.push(Some((i as u32, action.clone())));
                    depth.push(depth[i] + 1);
                    states.push(next.clone());
                    if let Err(violation) = m.invariant(&next) {
                        let trace = trace_to(id, &states, &preds);
                        return Err(Box::new(CheckFailure::Invariant { violation, trace }));
                    }
                    if m.is_goal(&next) {
                        goals += 1;
                    }
                    id
                }
            };
            if cfg.record_graph || cfg.check_liveness {
                edges.push((i as u32, action.clone(), to));
            }
        }
        i += 1;
    }

    if cfg.check_liveness {
        // backward BFS from every goal state over the reversed graph;
        // any state left unmarked can never flush out to a goal
        let n = states.len();
        let mut rev: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(from, _, to) in &edges {
            rev[to as usize].push(from);
        }
        let mut reaches_goal = vec![false; n];
        let mut queue: Vec<u32> = (0..n as u32)
            .filter(|&id| m.is_goal(&states[id as usize]))
            .collect();
        for &id in &queue {
            reaches_goal[id as usize] = true;
        }
        let mut qi = 0;
        while qi < queue.len() {
            let id = queue[qi];
            qi += 1;
            for &p in &rev[id as usize] {
                if !reaches_goal[p as usize] {
                    reaches_goal[p as usize] = true;
                    queue.push(p);
                }
            }
        }
        // `states` is in BFS order, so the first unmarked index is a
        // minimal-depth counterexample
        if let Some(bad) = (0..n).find(|&id| !reaches_goal[id]) {
            let trace = trace_to(bad as u32, &states, &preds);
            return Err(Box::new(CheckFailure::Liveness { trace }));
        }
    }

    let max_depth = depth.iter().copied().max().unwrap_or(0) as usize;
    let graph = cfg.record_graph.then_some(Graph { states, edges });
    Ok(Report {
        states: index.len(),
        transitions,
        depth: max_depth,
        terminal,
        goals,
        graph,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A counter that walks 0..=max by +1/+2 steps; goal = max. With an
    /// optional "trap" value that silently swallows further actions.
    struct Counter {
        max: u32,
        trap: Option<u32>,
        bad_invariant_at: Option<u32>,
    }

    impl Machine for Counter {
        type State = u32;
        type Action = u32; // increment size

        fn initial(&self) -> u32 {
            0
        }

        fn actions(&self, s: &u32, out: &mut Vec<u32>) {
            if Some(*s) == self.trap {
                return; // terminal non-goal unless trap == max
            }
            for step in [1u32, 2] {
                if s + step <= self.max {
                    out.push(step);
                }
            }
        }

        fn transition(&self, s: &u32, a: &u32) -> Result<u32, Violation> {
            Ok(s + a)
        }

        fn invariant(&self, s: &u32) -> Result<(), Violation> {
            if Some(*s) == self.bad_invariant_at {
                return Err(Violation::new(format!("hit forbidden value {s}")));
            }
            Ok(())
        }

        fn is_goal(&self, s: &u32) -> bool {
            *s == self.max
        }
    }

    fn counter(max: u32) -> Counter {
        Counter { max, trap: None, bad_invariant_at: None }
    }

    #[test]
    fn explores_every_state_exactly_once() {
        let m = counter(6);
        let r = explore(&m, &ExploreConfig::default()).unwrap();
        assert_eq!(r.states, 7); // 0..=6
        assert_eq!(r.goals, 1);
        assert_eq!(r.terminal, 1);
        assert_eq!(r.depth, 3); // 0 -2-> 2 -2-> 4 -2-> 6
        // transitions: from each s<max, +1 always; +2 when s+2<=max
        assert_eq!(r.transitions, 6 + 5);
        assert!(r.summary().contains("7 states"));
    }

    #[test]
    fn invariant_failure_has_shortest_trace() {
        let m = Counter { max: 8, trap: None, bad_invariant_at: Some(5) };
        let err = *explore(&m, &ExploreConfig::default()).unwrap_err();
        match err {
            CheckFailure::Invariant { violation, trace } => {
                assert!(violation.message().contains("forbidden value 5"));
                // shortest path to 5 is three steps: 2, 2, 1 (any order)
                assert_eq!(trace.len(), 3);
                assert_eq!(*trace.last(), 5);
                let rendered = trace.render(&m);
                assert!(rendered.contains("0. (init) 0"), "rendered={rendered}");
            }
            other => panic!("expected invariant failure, got {}", other.headline()),
        }
    }

    #[test]
    fn deadlock_detected_at_terminal_non_goal() {
        let m = Counter { max: 8, trap: Some(3), bad_invariant_at: None };
        let err = *explore(&m, &ExploreConfig::default()).unwrap_err();
        match err {
            CheckFailure::Deadlock { trace } => {
                assert_eq!(*trace.last(), 3);
                assert_eq!(trace.len(), 2); // 0 -2-> 2 -1-> 3
            }
            other => panic!("expected deadlock, got {}", other.headline()),
        }
    }

    #[test]
    fn trap_without_deadlock_check_is_liveness_violation() {
        let m = Counter { max: 8, trap: Some(3), bad_invariant_at: None };
        let cfg = ExploreConfig { check_deadlock: false, ..ExploreConfig::default() };
        let err = *explore(&m, &cfg).unwrap_err();
        match err {
            CheckFailure::Liveness { trace } => assert_eq!(*trace.last(), 3),
            other => panic!("expected liveness failure, got {}", other.headline()),
        }
    }

    #[test]
    fn state_limit_bails_out() {
        let m = counter(1_000);
        let cfg = ExploreConfig { max_states: 10, ..ExploreConfig::default() };
        let err = *explore(&m, &cfg).unwrap_err();
        assert!(matches!(err, CheckFailure::StateLimit { explored: 10 }));
    }

    #[test]
    fn transition_violation_reported_with_action() {
        struct Bad;
        impl Machine for Bad {
            type State = u32;
            type Action = ();
            fn initial(&self) -> u32 {
                0
            }
            fn actions(&self, s: &u32, out: &mut Vec<()>) {
                if *s == 0 {
                    out.push(());
                }
            }
            fn transition(&self, _: &u32, _: &()) -> Result<u32, Violation> {
                Err(Violation::new("bang"))
            }
        }
        let cfg = ExploreConfig { check_deadlock: false, check_liveness: false, ..Default::default() };
        let err = *explore(&Bad, &cfg).unwrap_err();
        match err {
            CheckFailure::Transition { violation, trace, .. } => {
                assert_eq!(violation.message(), "bang");
                assert!(trace.is_empty());
            }
            other => panic!("expected transition failure, got {}", other.headline()),
        }
    }

    #[test]
    fn dot_export_names_every_state() {
        let m = counter(3);
        let cfg = ExploreConfig { record_graph: true, ..ExploreConfig::default() };
        let r = explore(&m, &cfg).unwrap();
        let dot = r.dot(&m).expect("graph recorded");
        assert!(dot.starts_with("digraph explored {"));
        for i in 0..r.states {
            assert!(dot.contains(&format!("\"s{i}\"")), "missing node s{i} in {dot}");
        }
        assert!(dot.contains("doublecircle"), "goal state styled: {dot}");
        assert!(dot.contains("[label=1]") || dot.contains("[label=\"1\"]"), "dot={dot}");
        // without recording, no graph
        let r2 = explore(&m, &ExploreConfig::default()).unwrap();
        assert!(r2.dot(&m).is_none());
    }
}
