//! Exhaustive model checking for pure state machines, polestar-style.
//!
//! The coordinator's concurrency story is only as strong as its decision
//! logic, and threads can't be exhaustively tested. This module checks
//! the logic the threads *interpret*: implement [`Machine`] for a system
//! with explicit state, enumerable actions, and a pure transition
//! function, and [`explore`](explore::explore) walks **every** reachable
//! state breadth-first — checking safety invariants in each one, liveness
//! (every reachable state can still reach a goal) over the whole graph,
//! and reporting the shortest counterexample trace on any violation.
//!
//! BFS order means the first violation found is at minimal depth, so
//! counterexample traces are already minimized. The explored graph can be
//! exported as DOT through [`crate::diagram`] for the architecture docs.
//!
//! See [`crate::coordinator::shard_machine`] for the machine this was
//! built to check, and `mvap modelcheck` / `ci.sh` for the gate.

pub mod explore;
pub mod machine;

pub use explore::{explore, CheckFailure, ExploreConfig, Report, Trace};
pub use machine::{Machine, Violation};
