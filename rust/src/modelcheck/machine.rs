//! The [`Machine`] trait: a system as explicit states, enumerable
//! actions, and a pure transition function.

use std::fmt;
use std::hash::Hash;

/// A checked property did not hold. Carries a human-readable message;
/// the explorer attaches the state/trace context.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    message: String,
}

impl Violation {
    /// A violation with the given description.
    pub fn new(message: impl Into<String>) -> Self {
        Violation { message: message.into() }
    }

    /// The description.
    pub fn message(&self) -> &str {
        &self.message
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

/// A system the explorer can exhaustively check: explicit state,
/// enumerable actions per state, and a **pure** transition function.
/// Implementations must be deterministic — nondeterminism (scheduling,
/// timers) is modeled as distinct actions, never hidden inside
/// `transition`.
pub trait Machine {
    /// Full system state. `Eq + Hash` give the explorer state dedup;
    /// `Clone` lets transitions copy-and-mutate.
    type State: Clone + Eq + Hash + fmt::Debug;
    /// One atomic step the system can take from a state.
    type Action: Clone + fmt::Debug;

    /// The initial state.
    fn initial(&self) -> Self::State;

    /// Enumerate the actions enabled in `state` into `out` (cleared by
    /// the caller). An empty set marks a terminal state.
    fn actions(&self, state: &Self::State, out: &mut Vec<Self::Action>);

    /// Apply `action` to `state`. `Err` marks a safety violation
    /// *during* the step (e.g. an effect observed to double-execute);
    /// conditions checkable on the resulting state belong in
    /// [`Self::invariant`].
    fn transition(&self, state: &Self::State, action: &Self::Action)
        -> Result<Self::State, Violation>;

    /// Safety invariant, checked on the initial state and every state
    /// the explorer discovers.
    fn invariant(&self, _state: &Self::State) -> Result<(), Violation> {
        Ok(())
    }

    /// Is this a goal state? Goals feed the liveness check (every
    /// reachable state must be able to reach one) and terminal-state
    /// classification (a terminal non-goal is a deadlock).
    fn is_goal(&self, _state: &Self::State) -> bool {
        false
    }

    /// Short human-readable label for a state (traces, DOT nodes).
    fn state_label(&self, state: &Self::State) -> String {
        format!("{state:?}")
    }

    /// Short human-readable label for an action (traces, DOT edges).
    fn action_label(&self, action: &Self::Action) -> String {
        format!("{action:?}")
    }
}
