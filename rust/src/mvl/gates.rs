//! Ternary logic gates (Table IV) and generic MVL gate helpers.
//!
//! The paper's ternary decoder (Fig. 3) is built from the *standard*,
//! *positive* and *negative* ternary inverters (STI/PTI/NTI) plus
//! conventional binary gates; those primitives live here, the decoder
//! itself in [`crate::mvl::decoder`].

/// Standard ternary inverter: `STI(x) = 2 - x` (Table IV).
#[inline]
pub fn sti(x: u8) -> u8 {
    debug_assert!(x <= 2);
    2 - x
}

/// Positive ternary inverter: `PTI(0)=2, PTI(1)=2, PTI(2)=0` (Table IV).
#[inline]
pub fn pti(x: u8) -> u8 {
    debug_assert!(x <= 2);
    if x <= 1 { 2 } else { 0 }
}

/// Negative ternary inverter: `NTI(0)=2, NTI(1)=0, NTI(2)=0` (Table IV).
#[inline]
pub fn nti(x: u8) -> u8 {
    debug_assert!(x <= 2);
    if x == 0 { 2 } else { 0 }
}

/// Ternary AND = min (used when composing MVL gates; the paper's decoder
/// uses a *binary* AND on already-binary {0,2} signals, which coincides
/// with min on that domain).
#[inline]
pub fn tand(a: u8, b: u8) -> u8 {
    a.min(b)
}

/// Ternary OR = max.
#[inline]
pub fn tor(a: u8, b: u8) -> u8 {
    a.max(b)
}

/// Binary inverter on the {0,2} two-rail domain the decoder operates in
/// after the PTI/NTI stages ("conventional binary gates" in Fig. 3).
#[inline]
pub fn binv2(x: u8) -> u8 {
    debug_assert!(x == 0 || x == 2, "binv2 on non-binary rail {x}");
    2 - x
}

/// Generalised MVL inverter for radix n: `x ↦ (n-1) - x`.
#[inline]
pub fn mv_inv(x: u8, n: u8) -> u8 {
    debug_assert!(x < n);
    (n - 1) - x
}

/// Generalised "window literal" gate: outputs n-1 when `lo <= x <= hi`
/// else 0. PTI and NTI are the windows [0,1] and [0,0] composed with
/// inversion; window literals are the standard building block for MVL
/// decoders at arbitrary radix (§II-B's successive-approximation remark).
#[inline]
pub fn window(x: u8, lo: u8, hi: u8, n: u8) -> u8 {
    debug_assert!(x < n);
    if x >= lo && x <= hi { n - 1 } else { 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table IV, verbatim.
    #[test]
    fn table_iv_truth_tables() {
        assert_eq!([sti(0), sti(1), sti(2)], [2, 1, 0]);
        assert_eq!([pti(0), pti(1), pti(2)], [2, 2, 0]);
        assert_eq!([nti(0), nti(1), nti(2)], [2, 0, 0]);
    }

    #[test]
    fn min_max_gates() {
        assert_eq!(tand(1, 2), 1);
        assert_eq!(tor(1, 2), 2);
        for a in 0..3u8 {
            for b in 0..3u8 {
                // De Morgan with STI on the min/max algebra
                assert_eq!(sti(tand(a, b)), tor(sti(a), sti(b)));
                assert_eq!(sti(tor(a, b)), tand(sti(a), sti(b)));
            }
        }
    }

    #[test]
    fn window_generalises_ternary_inverters() {
        for x in 0..3u8 {
            assert_eq!(window(x, 0, 1, 3), pti(x));
            assert_eq!(window(x, 0, 0, 3), nti(x));
        }
    }

    #[test]
    fn mv_inv_involution() {
        for n in 2..6u8 {
            for x in 0..n {
                assert_eq!(mv_inv(mv_inv(x, n), n), x);
            }
        }
    }
}
