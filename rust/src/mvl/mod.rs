//! Multi-valued logic (MVL) primitives — §II of the paper.
//!
//! Radix-n ("n-ary") digits are called *nits*; radix-3 digits are *trits*.
//! The paper uses the **unbalanced** representation: logic value
//! `i ∈ [0, n-1]` is realised with voltage `i·V_DD/(n-1)`.

pub mod nit;
pub mod gates;
pub mod words;
pub mod decoder;

pub use nit::{Nit, Radix, DONT_CARE};
pub use words::Word;
