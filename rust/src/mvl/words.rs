//! Multi-digit radix-n words: conversions, arithmetic reference helpers.
//!
//! Words are stored **little-endian** (least-significant digit first), the
//! natural order for ripple-style digit-wise AP operation (§IV: "the process
//! is performed digit-wise and repeated for multi-digit operations").

use super::nit::Radix;

/// A little-endian, fixed-width, radix-n unsigned word.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Word {
    digits: Vec<u8>,
    radix: Radix,
}

impl Word {
    /// From raw little-endian digits.
    pub fn from_digits(digits: Vec<u8>, radix: Radix) -> Self {
        assert!(
            digits.iter().all(|&d| d < radix.n()),
            "invalid digit for radix {}",
            radix.n()
        );
        Word { digits, radix }
    }

    /// As [`Word::from_digits`], but allowing [`super::DONT_CARE`]
    /// wildcard digits — CAM search patterns and stored rows may be
    /// partially specified. Arithmetic helpers are undefined on wildcard
    /// words; the search ops ([`crate::ap::search`]) only compare them.
    pub fn from_digits_wild(digits: Vec<u8>, radix: Radix) -> Self {
        assert!(
            digits.iter().all(|&d| radix.valid(d)),
            "invalid digit for radix {}",
            radix.n()
        );
        Word { digits, radix }
    }

    /// Does any digit hold the [`super::DONT_CARE`] wildcard?
    pub fn has_dont_care(&self) -> bool {
        self.digits.iter().any(|&d| d == super::DONT_CARE)
    }

    /// Zero of a given width.
    pub fn zero(width: usize, radix: Radix) -> Self {
        Word { digits: vec![0; width], radix }
    }

    /// Encode `value` into `width` digits (truncating mod radix^width).
    pub fn from_u128(mut value: u128, width: usize, radix: Radix) -> Self {
        let n = radix.n() as u128;
        let digits = (0..width)
            .map(|_| {
                let d = (value % n) as u8;
                value /= n;
                d
            })
            .collect();
        Word { digits, radix }
    }

    /// Decode to a u128 (panics on overflow > 2^128, fine for test widths).
    pub fn to_u128(&self) -> u128 {
        let n = self.radix.n() as u128;
        self.digits
            .iter()
            .rev()
            .fold(0u128, |acc, &d| acc * n + d as u128)
    }

    /// Width in digits.
    pub fn width(&self) -> usize {
        self.digits.len()
    }

    /// Radix.
    pub fn radix(&self) -> Radix {
        self.radix
    }

    /// Little-endian digit slice.
    pub fn digits(&self) -> &[u8] {
        &self.digits
    }

    /// Mutable digit slice.
    pub fn digits_mut(&mut self) -> &mut [u8] {
        &mut self.digits
    }

    /// Reference (software) addition with carry-in, returning
    /// (sum word of the same width, carry-out digit). This is the oracle
    /// every AP adder run is checked against.
    pub fn add_ref(&self, other: &Word, carry_in: u8) -> (Word, u8) {
        assert_eq!(self.radix, other.radix);
        assert_eq!(self.width(), other.width());
        let n = self.radix.n() as u16;
        let mut carry = carry_in as u16;
        let mut out = Vec::with_capacity(self.width());
        for i in 0..self.width() {
            let s = self.digits[i] as u16 + other.digits[i] as u16 + carry;
            out.push((s % n) as u8);
            carry = s / n;
        }
        (Word::from_digits(out, self.radix), carry as u8)
    }

    /// Reference subtraction (self - other - borrow_in) mod radix^width,
    /// returning (difference, borrow-out).
    pub fn sub_ref(&self, other: &Word, borrow_in: u8) -> (Word, u8) {
        assert_eq!(self.radix, other.radix);
        assert_eq!(self.width(), other.width());
        let n = self.radix.n() as i16;
        let mut borrow = borrow_in as i16;
        let mut out = Vec::with_capacity(self.width());
        for i in 0..self.width() {
            let mut d = self.digits[i] as i16 - other.digits[i] as i16 - borrow;
            if d < 0 {
                d += n;
                borrow = 1;
            } else {
                borrow = 0;
            }
            out.push(d as u8);
        }
        (Word::from_digits(out, self.radix), borrow as u8)
    }
}

impl std::fmt::Display for Word {
    /// Most-significant digit first, e.g. "120₃".
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for &d in self.digits.iter().rev() {
            write!(f, "{d}")?;
        }
        Ok(())
    }
}

/// Convert a decimal value to fixed-width little-endian digits (helper for
/// hot paths that work on raw `u8` buffers instead of `Word`).
pub fn to_digits(value: u64, width: usize, radix: u8) -> Vec<u8> {
    let mut v = value;
    (0..width)
        .map(|_| {
            let d = (v % radix as u64) as u8;
            v /= radix as u64;
            d
        })
        .collect()
}

/// Inverse of [`to_digits`].
pub fn from_digits(digits: &[u8], radix: u8) -> u64 {
    digits
        .iter()
        .rev()
        .fold(0u64, |acc, &d| acc * radix as u64 + d as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};

    #[test]
    fn roundtrip_u128() {
        for v in [0u128, 1, 2, 5, 26, 27, 242, 1000] {
            let w = Word::from_u128(v, 8, Radix::TERNARY);
            assert_eq!(w.to_u128(), v % 3u128.pow(8));
        }
    }

    #[test]
    fn add_ref_matches_integers() {
        forall(Config::cases(300), |rng| {
            let radix = Radix(2 + rng.digit(4)); // radix 2..=5
            let width = 1 + rng.index(12);
            let a = rng.below(u64::MAX.into()) as u128;
            let b = rng.below(u64::MAX.into()) as u128;
            let cin = rng.digit(2);
            let wa = Word::from_u128(a, width, radix);
            let wb = Word::from_u128(b, width, radix);
            let (sum, cout) = wa.add_ref(&wb, cin);
            let modulus = (radix.n() as u128).pow(width as u32);
            let expect = wa.to_u128() + wb.to_u128() + cin as u128;
            assert_eq!(sum.to_u128(), expect % modulus);
            assert_eq!(cout as u128, expect / modulus);
        });
    }

    #[test]
    fn sub_then_add_roundtrip() {
        forall(Config::cases(300), |rng| {
            let radix = Radix(2 + rng.digit(3));
            let width = 1 + rng.index(10);
            let a = Word::from_u128(rng.next_u64() as u128, width, radix);
            let b = Word::from_u128(rng.next_u64() as u128, width, radix);
            let (diff, _borrow) = a.sub_ref(&b, 0);
            let (back, _carry) = diff.add_ref(&b, 0);
            assert_eq!(back.to_u128(), a.to_u128());
        });
    }

    #[test]
    fn display_msb_first() {
        let w = Word::from_digits(vec![0, 2, 1], Radix::TERNARY); // 1·9+2·3+0 = 15
        assert_eq!(format!("{w}"), "120");
        assert_eq!(w.to_u128(), 15);
    }

    #[test]
    fn raw_digit_helpers_roundtrip() {
        forall(Config::cases(200), |rng| {
            let radix = 2 + rng.digit(4);
            let width = 1 + rng.index(10);
            let modulus = (radix as u64).saturating_pow(width as u32);
            let v = rng.below(modulus.max(1));
            let d = to_digits(v, width, radix);
            assert_eq!(from_digits(&d, radix), v);
        });
    }
}
