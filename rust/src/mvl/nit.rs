//! The `Nit` digit type and radix descriptor.

/// Sentinel digit value for the "don't care" state ('X' in the paper).
/// Stored in a CAM cell as *all* memristors in R_HRS (Table I); as a search
/// key it matches every stored value (mask = 0 semantics are handled at the
/// register level, but `DONT_CARE` keys are also supported directly).
pub const DONT_CARE: u8 = u8::MAX;

/// A radix descriptor: the number of logic levels `n >= 2`.
///
/// Voltage realisation (unbalanced): level `i` ↦ `i * V_DD / (n-1)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Radix(pub u8);

impl Radix {
    pub const BINARY: Radix = Radix(2);
    pub const TERNARY: Radix = Radix(3);

    /// Number of levels.
    #[inline]
    pub fn n(self) -> u8 {
        self.0
    }

    /// All digit values `0..n`.
    pub fn digits(self) -> impl Iterator<Item = u8> {
        0..self.0
    }

    /// Is `d` a valid digit (or don't-care)?
    #[inline]
    pub fn valid(self, d: u8) -> bool {
        d < self.0 || d == DONT_CARE
    }

    /// Voltage level of digit `d` for supply `vdd` (unbalanced system).
    pub fn voltage(self, d: u8, vdd: f64) -> f64 {
        assert!(d < self.0, "voltage of invalid digit {d}");
        vdd * d as f64 / (self.0 - 1) as f64
    }

    /// Number of digits needed to represent values `< 2^bits`, i.e. the
    /// "equivalent width" used by the paper's binary-vs-ternary comparison
    /// (e.g. 32-bit ≈ 20-trit: ceil(32·ln2/ln3) = 21 — the paper pairs
    /// 32b with 20t, see [`crate::exp::table11`] for the exact pairing).
    pub fn digits_for_bits(self, bits: u32) -> u32 {
        ((bits as f64) * (2f64).ln() / (self.0 as f64).ln()).ceil() as u32
    }
}

/// A single n-valued digit paired with its radix. Most hot-path code uses
/// raw `u8` digits for compactness; `Nit` is the typed, validated wrapper
/// used at API boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Nit {
    value: u8,
    radix: Radix,
}

impl Nit {
    /// Construct a validated digit.
    pub fn new(value: u8, radix: Radix) -> Self {
        assert!(radix.valid(value), "digit {value} invalid for radix {}", radix.n());
        Nit { value, radix }
    }

    /// The don't-care digit.
    pub fn dont_care(radix: Radix) -> Self {
        Nit { value: DONT_CARE, radix }
    }

    /// Raw value (or [`DONT_CARE`]).
    #[inline]
    pub fn value(self) -> u8 {
        self.value
    }

    /// Radix.
    #[inline]
    pub fn radix(self) -> Radix {
        self.radix
    }

    /// Is this the don't-care digit?
    #[inline]
    pub fn is_dont_care(self) -> bool {
        self.value == DONT_CARE
    }

    /// Digit-wise match semantics of the CAM (Table III): don't-care on
    /// either side matches; otherwise exact equality.
    pub fn matches(self, other: Nit) -> bool {
        debug_assert_eq!(self.radix, other.radix);
        self.is_dont_care() || other.is_dont_care() || self.value == other.value
    }
}

impl std::fmt::Display for Nit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_dont_care() {
            write!(f, "x")
        } else {
            write!(f, "{}", self.value)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn radix_validity() {
        let t = Radix::TERNARY;
        assert!(t.valid(0) && t.valid(2) && t.valid(DONT_CARE));
        assert!(!t.valid(3));
    }

    #[test]
    fn unbalanced_voltages() {
        let t = Radix::TERNARY;
        assert_eq!(t.voltage(0, 0.8), 0.0);
        assert!((t.voltage(1, 0.8) - 0.4).abs() < 1e-12);
        assert!((t.voltage(2, 0.8) - 0.8).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn invalid_digit_panics() {
        Nit::new(3, Radix::TERNARY);
    }

    #[test]
    fn dont_care_matches_everything() {
        let t = Radix::TERNARY;
        let x = Nit::dont_care(t);
        for d in t.digits() {
            assert!(x.matches(Nit::new(d, t)));
            assert!(Nit::new(d, t).matches(x));
        }
        assert!(!Nit::new(0, t).matches(Nit::new(1, t)));
    }

    #[test]
    fn equivalent_widths() {
        // The paper pairs 8b↔5t, 16b↔10t, 32b↔20t, 51b↔32t, 64b↔40t, 128b↔80t
        // using floor-ish pairing p = q * ln2/ln3 rounded; our helper is the
        // ceil variant used for capacity checks.
        assert_eq!(Radix::TERNARY.digits_for_bits(8), 6);
        assert_eq!(Radix::TERNARY.digits_for_bits(3), 2);
        assert_eq!(Radix::BINARY.digits_for_bits(8), 8);
    }
}
