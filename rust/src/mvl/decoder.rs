//! Search-key n-ary decoder (§II-B, Table II; ternary circuit of Fig. 3).
//!
//! The decoder maps a (mask, key) pair to the signal vector
//! `(S_{n-1}, …, S_1, S_0)` driving the cell transistors:
//!
//! * mask = 0 (column inactive) → all signals 0 (no transistor conducts,
//!   the cell contributes no discharge path → unconditional match);
//! * mask = n-1 (column active), key = j → `S_j = 0`, all others = n-1.
//!
//! The logic is *inverting*: the searched-for position is the one driven
//! low. Two implementations are provided: a behavioural one for arbitrary
//! radix (the "successive-approximation ADC" route in the paper) and the
//! gate-level ternary circuit of Fig. 3 (Eqs. 1a–1c), which the tests prove
//! equivalent on the ternary domain.

use super::gates::{binv2, nti, pti, tand, tor};
use super::nit::{Radix, DONT_CARE};

/// Decoded signal vector, index i = S_i, values in logic levels {0, n-1}.
pub type Signals = Vec<u8>;

/// Behavioural decoder for arbitrary radix (Table II).
pub fn decode(radix: Radix, mask_active: bool, key: u8) -> Signals {
    let n = radix.n();
    if !mask_active || key == DONT_CARE {
        return vec![0; n as usize];
    }
    assert!(key < n, "key {key} invalid for radix {n}");
    (0..n).map(|i| if i == key { 0 } else { n - 1 }).collect()
}

/// Gate-level ternary decoder (Fig. 3 / Eqs. 1a–1c):
///
/// ```text
/// S2 = Mask · PTI(Key)
/// S1 = Mask · (NTI(Key) + ~PTI(Key))
/// S0 = Mask · ~NTI(Key)
/// ```
///
/// `mask` is a binary rail (0 or 2), `key` a trit.
pub fn decode_ternary_gates(mask: u8, key: u8) -> [u8; 3] {
    debug_assert!(mask == 0 || mask == 2, "mask is a binary {{0,2}} rail");
    debug_assert!(key <= 2);
    let p = pti(key); // {0,2} rail
    let nt = nti(key); // {0,2} rail
    let s2 = tand(mask, p); // Eq. (1a)
    let s1 = tand(mask, tor(nt, binv2(p))); // Eq. (1b)
    let s0 = tand(mask, binv2(nt)); // Eq. (1c)
    [s2, s1, s0]
}

/// Convenience: decode a full (key, mask) register pair into per-column
/// signal vectors. `keys[i]` may be [`DONT_CARE`]; `masks[i]` is a boolean
/// column-activation.
pub fn decode_registers(radix: Radix, keys: &[u8], masks: &[bool]) -> Vec<Signals> {
    assert_eq!(keys.len(), masks.len());
    keys.iter()
        .zip(masks)
        .map(|(&k, &m)| decode(radix, m, k))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table II for ternary: masked → all-zero; key j → S_j = 0, rest 2.
    #[test]
    fn table_ii_ternary() {
        let r = Radix::TERNARY;
        assert_eq!(decode(r, false, 0), vec![0, 0, 0]);
        assert_eq!(decode(r, true, 0), vec![0, 2, 2]); // index 0 = S_0
        assert_eq!(decode(r, true, 1), vec![2, 0, 2]);
        assert_eq!(decode(r, true, 2), vec![2, 2, 0]);
    }

    /// Fig. 3 truth table: (S2,S1,S0) = (2,2,0) for key 0, (2,0,2) for 1,
    /// (0,2,2) for 2, (0,0,0) when masked.
    #[test]
    fn fig3_gate_level() {
        assert_eq!(decode_ternary_gates(0, 0), [0, 0, 0]);
        assert_eq!(decode_ternary_gates(0, 1), [0, 0, 0]);
        assert_eq!(decode_ternary_gates(2, 0), [2, 2, 0]);
        assert_eq!(decode_ternary_gates(2, 1), [2, 0, 2]);
        assert_eq!(decode_ternary_gates(2, 2), [0, 2, 2]);
    }

    /// The gate-level circuit equals the behavioural decoder on ternary.
    #[test]
    fn gate_level_matches_behavioural() {
        let r = Radix::TERNARY;
        for key in 0..3u8 {
            for mask in [false, true] {
                let beh = decode(r, mask, key);
                let gat = decode_ternary_gates(if mask { 2 } else { 0 }, key);
                // behavioural is indexed S_0..S_2; gates return [S2,S1,S0]
                assert_eq!(beh[2], gat[0], "S2 key={key} mask={mask}");
                assert_eq!(beh[1], gat[1], "S1 key={key} mask={mask}");
                assert_eq!(beh[0], gat[2], "S0 key={key} mask={mask}");
            }
        }
    }

    /// Exactly one low signal when active, for every radix.
    #[test]
    fn one_hot_low_property() {
        for n in 2..8u8 {
            let r = Radix(n);
            for key in 0..n {
                let s = decode(r, true, key);
                assert_eq!(s.iter().filter(|&&v| v == 0).count(), 1);
                assert_eq!(s[key as usize], 0);
                assert!(s.iter().all(|&v| v == 0 || v == n - 1));
            }
        }
    }

    #[test]
    fn dont_care_key_decodes_inactive() {
        assert_eq!(decode(Radix::TERNARY, true, DONT_CARE), vec![0, 0, 0]);
    }

    #[test]
    fn register_decode_shapes() {
        let r = Radix::TERNARY;
        let sigs = decode_registers(r, &[0, 1, 2], &[true, false, true]);
        assert_eq!(sigs.len(), 3);
        assert_eq!(sigs[1], vec![0, 0, 0]);
        assert_eq!(sigs[2], vec![2, 2, 0]);
    }
}
