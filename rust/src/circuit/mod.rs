//! Analog circuit substrate — the HSPICE substitute (§VI-A).
//!
//! The paper characterises the "3T3R" cell with HSPICE on a 45 nm PTM
//! (V_t = 0.4 V, V_DD = 0.8 V): matchline dynamic range and compare energy
//! per match class, swept over R_L ∈ {20..100} kΩ and α = R_H/R_L ∈
//! {10..50} (Figs. 6–7). We rebuild that substrate from scratch:
//!
//! * [`solver`] — a small modified-nodal-analysis (MNA) transient solver:
//!   backward-Euler integration with Newton iteration for the nonlinear
//!   square-law NMOS model; dense LU for the linear solves.
//! * [`matchline`] — netlist builder for an MvCAM row's matchline under a
//!   given compare outcome (match class), plus precharge/evaluate
//!   simulation extracting V_ML(t), dynamic range, and compare energy.
//! * [`sweep`] — the §VI-A design-space exploration driving Figs. 6–7.

pub mod solver;
pub mod matchline;
pub mod sweep;

pub use matchline::{CellTech, MatchClass, MatchlineSim};
pub use solver::{Circuit, Element, TransientResult};
pub use sweep::{sweep_design_space, DesignPoint, SweepResult};
