//! §VI-A design-space exploration: sweep (R_L, α) and extract the dynamic
//! range (Fig. 6) and per-class compare energies (Fig. 7).

use super::matchline::{CellTech, MatchClass, MatchlineSim};

/// One (R_L, α) grid point's measurements.
#[derive(Clone, Copy, Debug)]
pub struct DesignPoint {
    pub r_l: f64,
    pub alpha: f64,
    /// Dynamic range, V.
    pub dr: f64,
    /// Compare energies [E_fm, E_1mm, E_2mm, E_3mm], J.
    pub energy: [f64; 4],
}

/// Full sweep output.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub points: Vec<DesignPoint>,
}

impl SweepResult {
    /// Look up a grid point.
    pub fn at(&self, r_l: f64, alpha: f64) -> Option<&DesignPoint> {
        self.points
            .iter()
            .find(|p| (p.r_l - r_l).abs() < 1.0 && (p.alpha - alpha).abs() < 1e-9)
    }

    /// The design point the paper adopts: best DR with lowest compare
    /// energy for that R_L — i.e. max DR, ties to max α.
    pub fn best(&self) -> &DesignPoint {
        self.points
            .iter()
            .max_by(|a, b| {
                (a.dr, a.alpha)
                    .partial_cmp(&(b.dr, b.alpha))
                    .unwrap()
            })
            .expect("empty sweep")
    }
}

/// Run the paper's sweep: R_L ∈ {20, 30, 50, 100} kΩ, α ∈ {10..50},
/// ternary cell, 3 masked cells (1-trit add compare), N = 41-cell rows
/// (inactive cells contribute no paths, so N only matters for parasitics
/// we do not model — recorded in DESIGN.md).
pub fn sweep_design_space(base: CellTech) -> SweepResult {
    let r_ls = [20e3, 30e3, 50e3, 100e3];
    let alphas = [10.0, 20.0, 30.0, 40.0, 50.0];
    let mut points = Vec::new();
    for &r_l in &r_ls {
        for &alpha in &alphas {
            let sim = MatchlineSim {
                tech: base.with_resistances(r_l, alpha),
                masked_cells: 3,
            };
            let energy = [
                sim.compare_energy(MatchClass(0)),
                sim.compare_energy(MatchClass(1)),
                sim.compare_energy(MatchClass(2)),
                sim.compare_energy(MatchClass(3)),
            ];
            points.push(DesignPoint { r_l, alpha, dr: sim.dynamic_range(), energy });
        }
    }
    SweepResult { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep() -> SweepResult {
        sweep_design_space(CellTech::ternary_default())
    }

    #[test]
    fn grid_is_complete() {
        let s = sweep();
        assert_eq!(s.points.len(), 20);
        assert!(s.at(20e3, 50.0).is_some());
        assert!(s.at(100e3, 10.0).is_some());
    }

    /// Fig. 6: "The maximum, thus, best dynamic range is observed for
    /// lowest R_L values … DR ≈ 240 mV when R_L = 20 kΩ and α = 50."
    #[test]
    fn best_point_is_paper_choice() {
        let s = sweep();
        let best = s.best();
        assert_eq!(best.r_l, 20e3);
        assert_eq!(best.alpha, 50.0);
        assert!((0.20..=0.31).contains(&best.dr), "DR={}", best.dr);
    }

    /// Fig. 7: at R_L = 20 kΩ, energies fall as α rises, for every class.
    #[test]
    fn energy_decreases_with_alpha() {
        let s = sweep();
        for class in 0..4 {
            let mut prev = f64::MAX;
            for &alpha in &[10.0, 20.0, 30.0, 40.0, 50.0] {
                let e = s.at(20e3, alpha).unwrap().energy[class];
                assert!(e < prev, "class {class} α={alpha}");
                prev = e;
            }
        }
    }

    /// DR monotone in both axes at the paper's grid: increases with α,
    /// decreases with R_L.
    #[test]
    fn dr_monotonicity() {
        let s = sweep();
        for &r_l in &[20e3, 30e3, 50e3, 100e3] {
            let mut prev = -1.0;
            for &alpha in &[10.0, 20.0, 30.0, 40.0, 50.0] {
                let dr = s.at(r_l, alpha).unwrap().dr;
                assert!(dr > prev, "r_l={r_l} alpha={alpha}");
                prev = dr;
            }
        }
    }
}
