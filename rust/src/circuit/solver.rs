//! A compact MNA (modified nodal analysis) transient solver.
//!
//! Scope: the circuits this library simulates are matchline discharge
//! networks — capacitors, resistors, square-law NMOS devices and ideal
//! sources — with a handful of nodes, so a dense-LU Newton/backward-Euler
//! solver is both simple and exact enough. Element multiplicity (`mult`)
//! lets N identical parallel paths be modelled as one element carrying
//! N× the current, which keeps 41-cell rows at 2–4 nodes.

/// Circuit elements. Node 0 is ground; nodes are `1..=num_nodes`.
#[derive(Clone, Debug)]
pub enum Element {
    /// Linear resistor between nodes a and b.
    Resistor { a: usize, b: usize, ohms: f64, mult: f64 },
    /// Capacitor between nodes a and b with initial voltage `ic` (V(a)-V(b)).
    Capacitor { a: usize, b: usize, farads: f64, ic: f64 },
    /// N-channel MOSFET, square-law model, gate driven by a fixed voltage
    /// during the analysed phase (signals are static per compare phase).
    /// Drain `d`, source `s`; conducts when V_GS > vt.
    Nmos { d: usize, s: usize, gate_v: f64, k: f64, vt: f64, mult: f64 },
    /// Ideal DC voltage source from node to ground (modelled as a Norton
    /// equivalent with a very large conductance).
    VSource { node: usize, volts: f64 },
}

/// A circuit: nodes + elements.
#[derive(Clone, Debug, Default)]
pub struct Circuit {
    num_nodes: usize,
    elements: Vec<Element>,
}

/// Result of a transient run.
#[derive(Clone, Debug)]
pub struct TransientResult {
    /// Time points (s).
    pub t: Vec<f64>,
    /// Node voltages per time point: `v[step][node-1]`.
    pub v: Vec<Vec<f64>>,
    /// Cumulative energy dissipated in resistive elements (J) per step.
    pub dissipated: Vec<f64>,
}

impl TransientResult {
    /// Voltage of `node` at the final time point.
    pub fn final_v(&self, node: usize) -> f64 {
        self.v.last().expect("empty transient")[node - 1]
    }

    /// Voltage of `node` at (or just after) time `time`.
    pub fn v_at(&self, node: usize, time: f64) -> f64 {
        let idx = self
            .t
            .iter()
            .position(|&ti| ti >= time)
            .unwrap_or(self.t.len() - 1);
        self.v[idx][node - 1]
    }

    /// Total dissipated energy (J).
    pub fn energy(&self) -> f64 {
        *self.dissipated.last().unwrap_or(&0.0)
    }
}

impl Circuit {
    /// New circuit with `num_nodes` non-ground nodes.
    pub fn new(num_nodes: usize) -> Self {
        Circuit { num_nodes, elements: Vec::new() }
    }

    /// Add an element.
    pub fn add(&mut self, e: Element) -> &mut Self {
        self.check(&e);
        self.elements.push(e);
        self
    }

    fn check(&self, e: &Element) {
        let ok = |n: usize| n <= self.num_nodes;
        let valid = match e {
            Element::Resistor { a, b, ohms, mult } => ok(*a) && ok(*b) && *ohms > 0.0 && *mult > 0.0,
            Element::Capacitor { a, b, farads, .. } => ok(*a) && ok(*b) && *farads > 0.0,
            Element::Nmos { d, s, k, mult, .. } => ok(*d) && ok(*s) && *k > 0.0 && *mult > 0.0,
            Element::VSource { node, .. } => *node >= 1 && ok(*node),
        };
        assert!(valid, "invalid element {e:?}");
    }

    /// Square-law NMOS drain current and transconductances.
    /// Returns (I_D, dI/dVd, dI/dVs) for drain/source voltages (vd, vs).
    fn nmos_current(gate_v: f64, vt: f64, k: f64, vd: f64, vs: f64) -> (f64, f64, f64) {
        // Handle reverse conduction by symmetry (drain/source swap).
        if vd < vs {
            let (i, did, dis) = Self::nmos_current(gate_v, vt, k, vs, vd);
            return (-i, -dis, -did);
        }
        let vgs = gate_v - vs;
        let vov = vgs - vt;
        if vov <= 0.0 {
            return (0.0, 0.0, 0.0);
        }
        let vds = vd - vs;
        if vds < vov {
            // triode: I = k (vov·vds − vds²/2)
            let i = k * (vov * vds - 0.5 * vds * vds);
            let did = k * (vov - vds);
            // dI/dvs = k(−vds·dvov/dvs... vov depends on vs) : I = k((g−vs−vt)(vd−vs) − (vd−vs)²/2)
            // dI/dvs = k(−(vd−vs) − (g−vs−vt) + (vd−vs)) = −k·vov
            let dis = -k * vov;
            (i, did, dis)
        } else {
            // saturation: I = k/2 · vov² (channel-length modulation ignored)
            let i = 0.5 * k * vov * vov;
            (i, 1e-12, -k * vov)
        }
    }

    /// Backward-Euler transient from 0 to `t_stop` with `steps` uniform
    /// steps. Initial node voltages come from capacitor `ic`s (nodes not
    /// touched by a capacitor start at 0, or at the source voltage if a
    /// VSource drives them).
    pub fn transient(&self, t_stop: f64, steps: usize) -> TransientResult {
        assert!(steps >= 1 && t_stop > 0.0);
        let n = self.num_nodes;
        let dt = t_stop / steps as f64;

        // initial condition
        let mut v = vec![0.0f64; n];
        for e in &self.elements {
            match *e {
                Element::Capacitor { a, b, ic, .. } => {
                    if a >= 1 && b == 0 {
                        v[a - 1] = ic;
                    } else if b >= 1 && a == 0 {
                        v[b - 1] = -ic;
                    } else if a >= 1 && b >= 1 {
                        v[a - 1] = ic; // relative IC against an assumed-0 b
                    }
                }
                Element::VSource { node, volts } => v[node - 1] = volts,
                _ => {}
            }
        }

        let mut out = TransientResult {
            t: vec![0.0],
            v: vec![v.clone()],
            dissipated: vec![0.0],
        };
        let mut energy = 0.0f64;

        for step in 1..=steps {
            let v_prev = v.clone();
            // Newton iteration on the BE system
            for _iter in 0..50 {
                let mut g = vec![vec![0.0f64; n]; n];
                let mut rhs = vec![0.0f64; n];
                let stamp_g = |g: &mut Vec<Vec<f64>>, i: usize, j: usize, val: f64| {
                    if i >= 1 && j >= 1 {
                        g[i - 1][j - 1] += val;
                    }
                };
                for e in &self.elements {
                    match *e {
                        Element::Resistor { a, b, ohms, mult } => {
                            let gc = mult / ohms;
                            stamp_g(&mut g, a, a, gc);
                            stamp_g(&mut g, b, b, gc);
                            stamp_g(&mut g, a, b, -gc);
                            stamp_g(&mut g, b, a, -gc);
                        }
                        Element::Capacitor { a, b, farads, .. } => {
                            let gc = farads / dt;
                            let vp = Self::node_v(&v_prev, a) - Self::node_v(&v_prev, b);
                            stamp_g(&mut g, a, a, gc);
                            stamp_g(&mut g, b, b, gc);
                            stamp_g(&mut g, a, b, -gc);
                            stamp_g(&mut g, b, a, -gc);
                            if a >= 1 {
                                rhs[a - 1] += gc * vp;
                            }
                            if b >= 1 {
                                rhs[b - 1] -= gc * vp;
                            }
                        }
                        Element::Nmos { d, s, gate_v, k, vt, mult } => {
                            let vd = Self::node_v(&v, d);
                            let vs = Self::node_v(&v, s);
                            let (i, did, dis) = Self::nmos_current(gate_v, vt, k, vd, vs);
                            let (i, did, dis) = (i * mult, did * mult, dis * mult);
                            // linearise: I ≈ i + did·(Vd − vd) + dis·(Vs − vs)
                            stamp_g(&mut g, d, d, did);
                            stamp_g(&mut g, d, s, dis);
                            stamp_g(&mut g, s, d, -did);
                            stamp_g(&mut g, s, s, -dis);
                            let i0 = i - did * vd - dis * vs;
                            if d >= 1 {
                                rhs[d - 1] -= i0;
                            }
                            if s >= 1 {
                                rhs[s - 1] += i0;
                            }
                        }
                        Element::VSource { node, volts } => {
                            let big = 1e3; // 1 kS ≫ any circuit conductance
                            stamp_g(&mut g, node, node, big);
                            rhs[node - 1] += big * volts;
                        }
                    }
                }
                let v_new = Self::solve_dense(g, rhs);
                let delta: f64 = v_new
                    .iter()
                    .zip(&v)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max);
                v = v_new;
                if delta < 1e-9 {
                    break;
                }
            }
            // accumulate resistive + transistor dissipation over the step
            for e in &self.elements {
                match *e {
                    Element::Resistor { a, b, ohms, mult } => {
                        let vd = Self::node_v(&v, a) - Self::node_v(&v, b);
                        energy += mult * vd * vd / ohms * dt;
                    }
                    Element::Nmos { d, s, gate_v, k, vt, mult } => {
                        let vd = Self::node_v(&v, d);
                        let vs = Self::node_v(&v, s);
                        let (i, _, _) = Self::nmos_current(gate_v, vt, k, vd, vs);
                        energy += mult * i * (vd - vs) * dt;
                    }
                    _ => {}
                }
            }
            out.t.push(step as f64 * dt);
            out.v.push(v.clone());
            out.dissipated.push(energy);
        }
        out
    }

    #[inline]
    fn node_v(v: &[f64], node: usize) -> f64 {
        if node == 0 {
            0.0
        } else {
            v[node - 1]
        }
    }

    /// Dense Gaussian elimination with partial pivoting.
    fn solve_dense(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
        let n = b.len();
        for col in 0..n {
            // pivot
            let piv = (col..n)
                .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
                .unwrap();
            a.swap(col, piv);
            b.swap(col, piv);
            let diag = a[col][col];
            assert!(diag.abs() > 1e-30, "singular MNA matrix (floating node?)");
            for row in col + 1..n {
                let f = a[row][col] / diag;
                if f == 0.0 {
                    continue;
                }
                for k in col..n {
                    a[row][k] -= f * a[col][k];
                }
                b[row] -= f * b[col];
            }
        }
        let mut x = vec![0.0; n];
        for row in (0..n).rev() {
            let mut s = b[row];
            for k in row + 1..n {
                s -= a[row][k] * x[k];
            }
            x[row] = s / a[row][row];
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RC discharge: V(t) = V0·exp(−t/RC), checked at 1τ and 2τ.
    #[test]
    fn rc_discharge_matches_analytic() {
        let mut c = Circuit::new(1);
        c.add(Element::Capacitor { a: 1, b: 0, farads: 100e-15, ic: 0.8 });
        c.add(Element::Resistor { a: 1, b: 0, ohms: 10_000.0, mult: 1.0 });
        let tau = 10_000.0 * 100e-15; // 1 ns
        let r = c.transient(2.0 * tau, 2000);
        let v1 = r.v_at(1, tau);
        assert!((v1 - 0.8 * (-1.0f64).exp()).abs() < 0.002, "v(τ)={v1}");
        let v2 = r.final_v(1);
        assert!((v2 - 0.8 * (-2.0f64).exp()).abs() < 0.002, "v(2τ)={v2}");
    }

    /// Parallel multiplicity: 6 identical paths == 1 path at mult 6.
    #[test]
    fn multiplicity_equivalence() {
        let run = |mult: f64, copies: usize| {
            let mut c = Circuit::new(1);
            c.add(Element::Capacitor { a: 1, b: 0, farads: 100e-15, ic: 0.8 });
            for _ in 0..copies {
                c.add(Element::Resistor { a: 1, b: 0, ohms: 1e6, mult });
            }
            c.transient(1e-9, 200).final_v(1)
        };
        assert!((run(6.0, 1) - run(1.0, 6)).abs() < 1e-9);
    }

    /// Energy conservation in RC discharge: dissipated = ΔE_cap.
    #[test]
    fn rc_energy_balance() {
        let mut c = Circuit::new(1);
        c.add(Element::Capacitor { a: 1, b: 0, farads: 100e-15, ic: 0.8 });
        c.add(Element::Resistor { a: 1, b: 0, ohms: 50_000.0, mult: 1.0 });
        let r = c.transient(20e-9, 4000);
        let vf = r.final_v(1);
        let de = 0.5 * 100e-15 * (0.8 * 0.8 - vf * vf);
        assert!(
            (r.energy() - de).abs() / de < 0.01,
            "dissipated {} vs ΔE {}",
            r.energy(),
            de
        );
    }

    /// NMOS with grounded source in series with R behaves like a reduced
    /// resistance: on-resistance ≈ 1/(k·V_ov) in deep triode.
    #[test]
    fn nmos_series_discharge() {
        let k = 5e-4; // 1/(k·0.4) = 5 kΩ
        let mut c = Circuit::new(2);
        c.add(Element::Capacitor { a: 1, b: 0, farads: 100e-15, ic: 0.8 });
        c.add(Element::Resistor { a: 1, b: 2, ohms: 20_000.0, mult: 1.0 });
        c.add(Element::Nmos { d: 2, s: 0, gate_v: 0.8, k, vt: 0.4, mult: 1.0 });
        let r = c.transient(5e-9, 1000);
        // Effective tau ≈ (20k + ~5k) * 100 fF = 2.5 ns
        let v = r.v_at(1, 2.5e-9);
        assert!((v - 0.8 * (-1.0f64).exp()).abs() < 0.05, "v={v}");
        // monotone decay
        for w in r.v.windows(2) {
            assert!(w[1][0] <= w[0][0] + 1e-12);
        }
    }

    /// Gate below threshold: no conduction, capacitor holds.
    #[test]
    fn nmos_off_no_discharge() {
        let mut c = Circuit::new(2);
        c.add(Element::Capacitor { a: 1, b: 0, farads: 100e-15, ic: 0.8 });
        c.add(Element::Resistor { a: 1, b: 2, ohms: 20_000.0, mult: 1.0 });
        c.add(Element::Nmos { d: 2, s: 0, gate_v: 0.3, k: 5e-4, vt: 0.4, mult: 1.0 });
        let r = c.transient(5e-9, 500);
        assert!((r.final_v(1) - 0.8).abs() < 1e-6);
    }

    /// VSource pins its node.
    #[test]
    fn vsource_pins_node() {
        let mut c = Circuit::new(2);
        c.add(Element::VSource { node: 1, volts: 0.8 });
        c.add(Element::Resistor { a: 1, b: 2, ohms: 1000.0, mult: 1.0 });
        c.add(Element::Resistor { a: 2, b: 0, ohms: 1000.0, mult: 1.0 });
        let r = c.transient(1e-9, 10);
        assert!((r.final_v(1) - 0.8).abs() < 1e-3);
        assert!((r.final_v(2) - 0.4).abs() < 1e-3);
    }

    #[test]
    #[should_panic(expected = "invalid element")]
    fn rejects_bad_element() {
        Circuit::new(1).add(Element::Resistor { a: 1, b: 0, ohms: -5.0, mult: 1.0 });
    }
}
