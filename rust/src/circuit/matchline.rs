//! Matchline netlists and the §VI-A measurements.
//!
//! During evaluate, every *masked* cell contributes one discharge path per
//! memristor whose select signal is high: ML —[R_mem]—[NMOS]— GND. For the
//! nTnR cell under a compare:
//!
//! * a **matching** cell: the searched position's signal is low (its LRS
//!   memristor disconnected); the other (n−1) signals are high over HRS
//!   memristors → (n−1) HRS paths;
//! * a **mismatching** cell storing j ≠ key i: S_j is high over the LRS
//!   memristor → 1 LRS path, plus (n−2) HRS paths (high signals over HRS),
//!   the searched position's HRS memristor being disconnected.
//!
//! Identical paths are collapsed via element multiplicity, so a 41-cell row
//! solves on a 3-node MNA system.

use super::solver::{Circuit, Element, TransientResult};

/// Technology parameters for the cell and matchline (defaults = §VI-A).
#[derive(Clone, Copy, Debug)]
pub struct CellTech {
    /// Radix (n of nTnR). Ternary cell = 3.
    pub n: u8,
    /// Low-resistance state (Ω).
    pub r_lrs: f64,
    /// High-resistance state (Ω).
    pub r_hrs: f64,
    /// Matchline/comparator load capacitance (F). Paper: 100 fF.
    pub c_load: f64,
    /// Supply voltage (V). Paper: 0.8 V.
    pub vdd: f64,
    /// NMOS threshold (V). Paper (45 nm PTM): 0.4 V.
    pub vt: f64,
    /// NMOS transconductance k = µCox·W/L (A/V²); 5e-4 gives
    /// R_on ≈ 5 kΩ at V_ov = 0.4 V, a typical 45 nm access-device sizing.
    pub k: f64,
    /// Evaluate time (s). Paper: 1 ns.
    pub t_eval: f64,
}

impl CellTech {
    /// §VI-A ternary design point: R_L = 20 kΩ, α = 50.
    pub fn ternary_default() -> Self {
        CellTech {
            n: 3,
            r_lrs: 20e3,
            r_hrs: 1e6,
            c_load: 100e-15,
            vdd: 0.8,
            vt: 0.4,
            k: 5e-4,
            t_eval: 1e-9,
        }
    }

    /// Binary (2T2R) variant at the same design point.
    pub fn binary_default() -> Self {
        CellTech { n: 2, ..Self::ternary_default() }
    }

    /// With a different (R_L, α) pair.
    pub fn with_resistances(mut self, r_l: f64, alpha: f64) -> Self {
        self.r_lrs = r_l;
        self.r_hrs = alpha * r_l;
        self
    }
}

/// Compare outcome class for a row: number of mismatching masked cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatchClass(pub usize);

impl MatchClass {
    pub const FULL_MATCH: MatchClass = MatchClass(0);
}

/// Matchline simulator for a row with `masked_cells` active columns.
#[derive(Clone, Copy, Debug)]
pub struct MatchlineSim {
    pub tech: CellTech,
    /// Cells activated by the compare mask (3 for a 1-digit add pass).
    pub masked_cells: usize,
}

impl MatchlineSim {
    /// Build the evaluate-phase netlist for a row whose compare outcome is
    /// `class` (k mismatching cells out of `masked_cells`).
    ///
    /// Nodes: 1 = matchline; 2 = LRS-path internal node; 3 = HRS-path
    /// internal node (multiplicity collapses identical paths).
    pub fn netlist(&self, class: MatchClass) -> Circuit {
        let k = class.0;
        let m = self.masked_cells;
        assert!(k <= m, "more mismatches than masked cells");
        let t = &self.tech;
        let n = t.n as f64;
        // path counts (see module docs)
        let lrs_paths = k as f64;
        let hrs_paths = (m - k) as f64 * (n - 1.0) + k as f64 * (n - 2.0);
        let mut c = Circuit::new(3);
        c.add(Element::Capacitor { a: 1, b: 0, farads: t.c_load, ic: t.vdd });
        if lrs_paths > 0.0 {
            c.add(Element::Resistor { a: 1, b: 2, ohms: t.r_lrs, mult: lrs_paths });
            c.add(Element::Nmos { d: 2, s: 0, gate_v: t.vdd, k: t.k * lrs_paths, vt: t.vt, mult: 1.0 });
        } else {
            // keep node 2 grounded to avoid a floating node
            c.add(Element::Resistor { a: 2, b: 0, ohms: 1e12, mult: 1.0 });
        }
        if hrs_paths > 0.0 {
            c.add(Element::Resistor { a: 1, b: 3, ohms: t.r_hrs, mult: hrs_paths });
            c.add(Element::Nmos { d: 3, s: 0, gate_v: t.vdd, k: t.k * hrs_paths, vt: t.vt, mult: 1.0 });
        } else {
            c.add(Element::Resistor { a: 3, b: 0, ohms: 1e12, mult: 1.0 });
        }
        c
    }

    /// Simulate the evaluate phase; returns the transient.
    pub fn evaluate(&self, class: MatchClass) -> TransientResult {
        self.netlist(class).transient(self.tech.t_eval, 400)
    }

    /// V_ML after the evaluate time.
    pub fn ml_voltage(&self, class: MatchClass) -> f64 {
        self.evaluate(class).final_v(1)
    }

    /// Dynamic range (Eq. 2): `DR = V_fm − V_1mm` after 1 ns of evaluate.
    pub fn dynamic_range(&self) -> f64 {
        self.ml_voltage(MatchClass(0)) - self.ml_voltage(MatchClass(1))
    }

    /// Compare energy for a row of the given class: capacitor energy
    /// released over the evaluate phase, `½·C·(V_DD² − V_end²)` — the
    /// charge the precharge phase must restore.
    pub fn compare_energy(&self, class: MatchClass) -> f64 {
        let v_end = self.ml_voltage(class);
        let t = &self.tech;
        0.5 * t.c_load * (t.vdd * t.vdd - v_end * v_end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> MatchlineSim {
        MatchlineSim { tech: CellTech::ternary_default(), masked_cells: 3 }
    }

    /// §II-A: "In the case of a match, the voltage of the ML discharges
    /// slowly and is hence preserved high, whereas in the case of a
    /// mismatch, the ML discharges quickly to ground."
    #[test]
    fn match_high_mismatch_low() {
        let s = sim();
        let v_fm = s.ml_voltage(MatchClass(0));
        let v_1mm = s.ml_voltage(MatchClass(1));
        assert!(v_fm > 0.7, "v_fm={v_fm}");
        assert!(v_1mm < 0.55, "v_1mm={v_1mm}");
        assert!(v_fm > v_1mm + 0.2);
    }

    /// More mismatches ⇒ faster discharge ⇒ lower V and higher energy.
    #[test]
    fn monotone_in_class() {
        let s = sim();
        let vs: Vec<f64> = (0..=3).map(|k| s.ml_voltage(MatchClass(k))).collect();
        for w in vs.windows(2) {
            assert!(w[0] > w[1]);
        }
        let es: Vec<f64> = (0..=3).map(|k| s.compare_energy(MatchClass(k))).collect();
        for w in es.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    /// §VI-A Fig. 6 anchor: DR ≈ 240 mV at R_L = 20 kΩ, α = 50 (we accept
    /// the 200–300 mV band — the exact figure depends on the PTM card).
    #[test]
    fn dynamic_range_anchor() {
        let dr = sim().dynamic_range();
        assert!((0.20..=0.31).contains(&dr), "DR={dr}");
    }

    /// The evaluate-time DR band of §VI-B: "we observe a DR approximately
    /// equal to 200mV for the different simulations" for both binary and
    /// ternary rows.
    #[test]
    fn binary_row_dr_band() {
        let s = MatchlineSim { tech: CellTech::binary_default(), masked_cells: 3 };
        let dr = s.dynamic_range();
        assert!(dr > 0.15, "binary DR={dr}");
    }

    /// DR improves as R_L decreases (Fig. 6's main trend): walking the grid
    /// from 100 kΩ down to 20 kΩ, DR rises monotonically.
    #[test]
    fn dr_increases_with_lower_rl() {
        let mut prev = 0.0;
        for r_l in [100e3, 50e3, 30e3, 20e3] {
            let s = MatchlineSim {
                tech: CellTech::ternary_default().with_resistances(r_l, 50.0),
                masked_cells: 3,
            };
            let dr = s.dynamic_range();
            assert!(dr > prev, "DR not increasing at R_L={r_l}: {dr} vs {prev}");
            prev = dr;
        }
    }

    /// E_fm drops steeply with α while E_3mm barely moves (Fig. 7: −71.6 %
    /// vs −4.4 % from α=10 to α=50 at R_L = 20 kΩ).
    #[test]
    fn fig7_alpha_sensitivity() {
        let e = |alpha: f64, class: usize| {
            MatchlineSim {
                tech: CellTech::ternary_default().with_resistances(20e3, alpha),
                masked_cells: 3,
            }
            .compare_energy(MatchClass(class))
        };
        let fm_drop = 1.0 - e(50.0, 0) / e(10.0, 0);
        let mm3_drop = 1.0 - e(50.0, 3) / e(10.0, 3);
        assert!((0.55..=0.85).contains(&fm_drop), "fm drop {fm_drop}");
        assert!((0.0..=0.15).contains(&mm3_drop), "3mm drop {mm3_drop}");
        assert!(fm_drop > 5.0 * mm3_drop);
    }

    /// Unmasked rows (0 masked cells) hold their charge: no paths.
    #[test]
    fn no_masked_cells_holds() {
        let s = MatchlineSim { tech: CellTech::ternary_default(), masked_cells: 0 };
        let v = s.ml_voltage(MatchClass(0));
        assert!((v - 0.8).abs() < 1e-6);
    }
}
