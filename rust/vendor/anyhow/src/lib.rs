//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow)
//! crate — the offline crate set has no registry access, so the subset of
//! the API this repository uses is vendored here:
//!
//! * [`Error`] — an opaque, message-carrying error type convertible from
//!   any `std::error::Error` via `?` (the source chain is flattened into
//!   the message rather than retained).
//! * [`Result`] — `Result<T, Error>` with the error type defaulted.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros.
//!
//! The crate is intentionally API-compatible for this subset: replacing
//! the `path` dependency with `anyhow = "1"` requires no source changes.

use std::fmt;

/// An opaque error carrying a rendered message.
pub struct Error {
    msg: String,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Build an error from a standard error value.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (the alternate chain format) degrades to the flat message.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from any standard error. `Error` itself deliberately does
// NOT implement `std::error::Error`, exactly like the real `anyhow::Error`,
// so this blanket impl cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tokens:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tokens)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($tokens:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($tokens)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_format() {
        let value = 7;
        let e = anyhow!("bad value {value}");
        assert_eq!(e.to_string(), "bad value 7");
        let e = anyhow!("bad value {}", 9);
        assert_eq!(e.to_string(), "bad value 9");
        let e = anyhow!(String::from("plain"));
        assert_eq!(format!("{e:#}"), "plain");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
    }
}
