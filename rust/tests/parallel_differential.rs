//! Differential property tests for the data-parallel word-block
//! execution layer (PR 8): a parallel [`Ap`] must be *bit-identical* to
//! the sequential bit-sliced path and to the scalar reference — same
//! extracted values, same [`ApStats`] (cycles, set/reset ops, rows
//! written, mismatch histogram), same priced energy, same modeled delay,
//! same stored digits — across radices 2–5, word-boundary and mid-word
//! row counts, don't-care densities (which force the faithful fallback
//! mid-kernel), segmented per-job attribution, and thread counts
//! 1/2/3/8. Every sweep replays with `MVAP_PROP_SEED=0x…`.

mod common;

use common::{boundary_rows, random_digit, random_radix, random_words};
use mvap::ap::{adder_lut, extract_operand, load_operands_storage, Ap, ApStats, ExecMode};
use mvap::cam::{CamStorage, Parallelism, StorageKind};
use mvap::energy::{delay_cycles, DelayScheme, EnergyModel, OpShape};
use mvap::mvl::Radix;
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

/// Thread counts every differential sweep runs: 1 (must be the literal
/// sequential code path), 2, an odd count (uneven block sizes), and more
/// threads than most test arrays have word blocks.
const THREADS: [usize; 4] = [1, 2, 3, 8];

/// A parallelism knob that partitions even tiny test arrays: block
/// granularity of one 64-row word instead of the production default.
fn fine_grained(threads: usize) -> Parallelism {
    Parallelism { threads, min_block_words: 1 }
}

/// Run the multi-position fast path on one storage/parallelism config
/// and return every observable: extracted values, stats, digits, priced
/// energy, and the modeled delay.
struct Observed {
    values: Vec<(mvap::mvl::Word, u8)>,
    stats: ApStats,
    digits: Vec<u8>,
    energy: mvap::energy::EnergyBreakdown,
    delay: u64,
}

fn run_fast_path(
    kind: StorageKind,
    par: Option<Parallelism>,
    radix: Radix,
    a: &[mvap::mvl::Word],
    b: &[mvap::mvl::Word],
    mode: ExecMode,
) -> Observed {
    let lut = adder_lut(radix, mode);
    let (storage, layout) = load_operands_storage(kind, radix, a, b, None);
    let mut ap = Ap::with_storage(storage);
    if let Some(par) = par {
        ap = ap.with_parallelism(par);
    }
    ap.apply_lut_multi_fast(&lut, &layout.positions(), mode);
    let values = extract_operand(ap.storage(), &layout);
    let stats = ap.take_stats();
    let energy = EnergyModel::ternary_default().price(&stats);
    let delay = delay_cycles(OpShape::of(&lut, layout.positions().len()), DelayScheme::Traditional);
    Observed { values, stats, digits: ap.storage().to_digits(), energy, delay }
}

/// Random operands (with don't-care digits mixed in, so some kernel
/// applications abort to the faithful path mid-flight): every thread
/// count must reproduce the scalar reference and the sequential
/// bit-sliced run exactly — values, stats, energy, delay, contents.
#[test]
fn parallel_agrees_with_sequential_and_scalar() {
    forall(Config::cases(60), |rng: &mut Rng| {
        let radix = random_radix(rng);
        let p = 1 + rng.index(8);
        let rows = boundary_rows(rng);
        let mut a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);
        // sprinkle don't-cares into one operand to hit the abort path
        if rng.chance(0.3) {
            let digits: Vec<u8> =
                (0..p).map(|_| random_digit(rng, radix.n(), 0.3)).collect();
            a[rng.index(rows)] = mvap::mvl::Word::from_digits(digits, radix);
        }
        let mode = if rng.chance(0.5) { ExecMode::Blocked } else { ExecMode::NonBlocked };

        let scalar = run_fast_path(StorageKind::Scalar, None, radix, &a, &b, mode);
        let seq = run_fast_path(StorageKind::BitSliced, None, radix, &a, &b, mode);
        assert_eq!(scalar.values, seq.values, "scalar vs sequential values (rows={rows})");
        assert_eq!(scalar.stats, seq.stats, "scalar vs sequential stats (rows={rows})");

        for threads in THREADS {
            let par = run_fast_path(
                StorageKind::BitSliced,
                Some(fine_grained(threads)),
                radix,
                &a,
                &b,
                mode,
            );
            let ctx = format!("threads={threads} radix={} rows={rows} {mode:?}", radix.n());
            assert_eq!(par.values, seq.values, "values ({ctx})");
            assert_eq!(par.stats, seq.stats, "stats ({ctx})");
            assert_eq!(par.digits, seq.digits, "contents ({ctx})");
            assert_eq!(par.energy, seq.energy, "energy ({ctx})");
            assert_eq!(par.delay, seq.delay, "delay ({ctx})");
        }
    });
}

/// Explicit word-boundary and mid-word row counts, radices 2–5: the
/// partitioned path must agree exactly where tail-word masking and
/// uneven block splits live.
#[test]
fn word_boundary_row_counts_agree() {
    for n in 2u8..=5 {
        let radix = Radix(n);
        for rows in [63usize, 64, 65, 127, 128, 129, 191, 300] {
            let mut rng = Rng::new(rows as u64 * 131 + n as u64);
            let p = 4;
            let a = random_words(&mut rng, rows, p, radix);
            let b = random_words(&mut rng, rows, p, radix);
            let seq = run_fast_path(StorageKind::BitSliced, None, radix, &a, &b, ExecMode::Blocked);
            for threads in [2usize, 8] {
                let par = run_fast_path(
                    StorageKind::BitSliced,
                    Some(fine_grained(threads)),
                    radix,
                    &a,
                    &b,
                    ExecMode::Blocked,
                );
                assert_eq!(par.values, seq.values, "values (n={n} rows={rows} t={threads})");
                assert_eq!(par.stats, seq.stats, "stats (n={n} rows={rows} t={threads})");
                assert_eq!(par.digits, seq.digits, "contents (n={n} rows={rows} t={threads})");
            }
        }
    }
}

/// The thread-count-invariance property of record (wired into ci.sh
/// stage 3): at production block granularity and 8k+ rows, every thread
/// count yields one identical `ApStats`/energy/delay/content tuple —
/// and the multi-threaded configurations actually engage the scoped
/// pool (non-zero drained [`mvap::ap::ParallelEvents`]).
#[test]
fn thread_count_invariance_at_production_granularity() {
    let radix = Radix::TERNARY;
    let p = 8;
    for rows in [8192usize, 8200, 16384] {
        let mut rng = Rng::new(rows as u64);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let lut = adder_lut(radix, ExecMode::Blocked);
        let mut reference: Option<(Vec<u8>, ApStats)> = None;
        for threads in THREADS {
            let (storage, layout) =
                load_operands_storage(StorageKind::BitSliced, radix, &a, &b, None);
            let mut ap =
                Ap::with_storage(storage).with_parallelism(Parallelism::new(threads));
            ap.apply_lut_multi_fast(&lut, &layout.positions(), ExecMode::Blocked);
            let digits = ap.storage().to_digits();
            let stats = ap.take_stats();
            let events = ap.take_parallel_events();
            if threads == 1 {
                assert_eq!(events.scopes, 0, "threads=1 must take the sequential path");
            } else {
                assert!(
                    events.scopes > 0 && events.blocks > events.scopes,
                    "threads={threads} rows={rows}: pool never engaged ({events:?})"
                );
            }
            match &reference {
                None => reference = Some((digits, stats)),
                Some((ref_digits, ref_stats)) => {
                    assert_eq!(&digits, ref_digits, "contents (threads={threads} rows={rows})");
                    assert_eq!(&stats, ref_stats, "stats (threads={threads} rows={rows})");
                    assert_eq!(
                        EnergyModel::ternary_default().price(&stats),
                        EnergyModel::ternary_default().price(ref_stats),
                        "energy (threads={threads} rows={rows})"
                    );
                }
            }
        }
    }
}

/// Segmented (coalesced-tile) execution: per-segment stats attribution
/// must be exact under partitioning — each job's `ApStats` and priced
/// energy identical to the sequential segmented run, for random segment
/// bounds that deliberately straddle block cuts.
#[test]
fn segmented_attribution_exact_across_threads() {
    forall(Config::cases(40), |rng: &mut Rng| {
        let radix = random_radix(rng);
        let p = 1 + rng.index(6);
        let rows = 64 + rng.index(400);
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);
        let mode = if rng.chance(0.5) { ExecMode::Blocked } else { ExecMode::NonBlocked };
        let lut = adder_lut(radix, mode);
        // random non-decreasing bounds covering all rows
        let nsegs = 1 + rng.index(5);
        let mut bounds: Vec<usize> = (0..nsegs - 1).map(|_| rng.index(rows + 1)).collect();
        bounds.push(rows);
        bounds.sort_unstable();

        let run = |par: Option<Parallelism>| {
            let (storage, layout) =
                load_operands_storage(StorageKind::BitSliced, radix, &a, &b, None);
            let mut ap = Ap::with_storage(storage);
            if let Some(par) = par {
                ap = ap.with_parallelism(par);
            }
            let segs =
                ap.apply_lut_multi_fast_segmented(&lut, &layout.positions(), mode, &bounds);
            (segs, ap.take_stats(), ap.storage().to_digits())
        };
        let (seq_segs, seq_stats, seq_digits) = run(None);
        for threads in [2usize, 3, 8] {
            let (par_segs, par_stats, par_digits) = run(Some(fine_grained(threads)));
            let ctx = format!("threads={threads} rows={rows} segs={bounds:?} {mode:?}");
            assert_eq!(par_segs, seq_segs, "per-segment stats ({ctx})");
            assert_eq!(par_stats, seq_stats, "total stats ({ctx})");
            assert_eq!(par_digits, seq_digits, "contents ({ctx})");
            let model = EnergyModel::ternary_default();
            for (i, (ps, ss)) in par_segs.iter().zip(&seq_segs).enumerate() {
                assert_eq!(model.price(ps), model.price(ss), "segment {i} energy ({ctx})");
            }
        }
    });
}

/// Plane-parallel row movement ([`Ap::copy_rows`]): above the size
/// threshold the per-plane scoped tasks must produce the same digits as
/// the sequential primitive, for both across-column and within-column
/// (overlap-free) moves, including misaligned bit offsets.
#[test]
fn copy_rows_parallel_agrees() {
    let radix = Radix::TERNARY;
    let rows = mvap::ap::COPY_PAR_MIN_ROWS + 65; // straddle the last word
    let cols = 2;
    let mut rng = Rng::new(97);
    let mut data = vec![0u8; rows * cols];
    for d in data.iter_mut() {
        *d = random_digit(&mut rng, 3, 0.1);
    }
    // (src_col, src_row, dst_col, dst_row, count): across columns with a
    // misaligned source, and within one column shifting downward.
    let moves = [
        (0usize, 1usize, 1usize, 0usize, mvap::ap::COPY_PAR_MIN_ROWS + 3),
        (0, 64, 0, 7, mvap::ap::COPY_PAR_MIN_ROWS),
    ];
    for (src_col, src_row, dst_col, dst_row, count) in moves {
        let storage =
            CamStorage::from_data(StorageKind::BitSliced, radix, rows, cols, &data);
        let mut seq = Ap::with_storage(storage.clone());
        seq.copy_rows(src_col, src_row, dst_col, dst_row, count);
        for threads in [2usize, 8] {
            let mut par =
                Ap::with_storage(storage.clone()).with_parallelism(Parallelism::new(threads));
            par.copy_rows(src_col, src_row, dst_col, dst_row, count);
            assert_eq!(
                par.storage().to_digits(),
                seq.storage().to_digits(),
                "copy ({src_col},{src_row})->({dst_col},{dst_row}) x{count} t={threads}"
            );
            let events = par.take_parallel_events();
            assert_eq!(events.scopes, 1, "copy must engage the pool once ({events:?})");
        }
    }
}
