//! Cross-module integration tests that need no AOT artifacts: LUT
//! generation → AP simulation → coordinator service, property tests on
//! coordinator invariants, and the coalescing/sharding differential
//! suite (coalesced execution must be value- and stats-exact vs solo).

use mvap::coordinator::batcher::{make_tiles, pad_classes, strip_padding};
use mvap::coordinator::{
    Backend, EngineService, Job, JobSignature, NativeBackend, OpKind, ShardConfig,
    ShardedService, VectorEngine,
};
use mvap::mvl::Radix;
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

mod common;

use common::random_words;

/// End-to-end through the threaded service: many concurrent jobs, several
/// ops and radices, all results exact.
#[test]
fn service_end_to_end_mixed_workload() {
    let svc = EngineService::start(4, 16, || {
        Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
    })
    .unwrap();
    let mut rng = Rng::new(404);
    let mut pending = Vec::new();
    for id in 0..24 {
        let radix = if id % 3 == 0 { Radix::BINARY } else { Radix::TERNARY };
        let op = match id % 3 {
            0 => OpKind::Add,
            1 => OpKind::Sub,
            _ => OpKind::Mac,
        };
        let p = 1 + (id as usize % 10);
        let rows = 1 + rng.index(300);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let job = Job::new(id, op, radix, id % 2 == 0, a.clone(), b.clone());
        pending.push((svc.submit(job), op, radix, a, b, id));
    }
    for (rx, op, radix, a, b, id) in pending {
        let res = rx.recv().unwrap().unwrap();
        assert_eq!(res.id, id);
        let n = radix.n() as u16;
        for r in 0..a.len() {
            let expect: Vec<u8> = match op {
                OpKind::Add => a[r].add_ref(&b[r], 0).0.digits().to_vec(),
                OpKind::Sub => a[r].sub_ref(&b[r], 0).0.digits().to_vec(),
                OpKind::Mac => {
                    let mut carry = 0u16;
                    a[r].digits()
                        .iter()
                        .zip(b[r].digits())
                        .map(|(&x, &y)| {
                            let v = x as u16 * y as u16 + carry;
                            carry = v / n;
                            (v % n) as u8
                        })
                        .collect()
                }
                OpKind::Reduce => unreachable!("this sweep submits element-wise ops only"),
            };
            assert_eq!(res.values[r].0.digits(), &expect[..], "job {id} row {r} {op:?}");
        }
    }
    let metrics = svc.shutdown();
    assert_eq!(metrics.jobs, 24);
}

/// Coordinator invariant: results are independent of tile size (padding
/// and splitting must not change values or live-row stats).
#[test]
fn tiling_invariance_property() {
    forall(Config::cases(20), |rng| {
        let radix = Radix::TERNARY;
        let p = 1 + rng.index(8);
        let rows = 1 + rng.index(600);
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);

        // Direct single-array reference (no tiling).
        use mvap::ap::{add_vectors, adder_lut, load_operands, Ap, ExecMode};
        let lut = adder_lut(radix, ExecMode::Blocked);
        let (array, layout) = load_operands(radix, &a, &b, None);
        let mut ap = Ap::new(array);
        let want = add_vectors(&mut ap, &layout, &lut, ExecMode::Blocked);
        let want_stats = ap.take_stats();

        // Coordinator path (DEFAULT_TILE_ROWS tiling + padding).
        let mut eng = mvap::coordinator::VectorEngine::new(Box::new(NativeBackend::default()));
        let job = Job::new(1, OpKind::Add, radix, true, a, b);
        let got = eng.execute(&job).unwrap();

        assert_eq!(got.values, want, "values differ under tiling");
        // live-row event counts match exactly after padding strip
        assert_eq!(
            got.stats.row_compares(),
            want_stats.row_compares(),
            "row compares (rows={rows} p={p})"
        );
        assert_eq!(got.stats.mismatch_hist, want_stats.mismatch_hist);
        assert_eq!(got.stats.sets, want_stats.sets);
    });
}

/// The threaded service over the bit-sliced backend kind produces the
/// same results as the scalar-native service.
#[test]
fn bitsliced_service_matches_native() {
    use mvap::coordinator::BackendKind;
    let run = |kind: BackendKind| {
        let svc = EngineService::start_kind(2, 4, kind, "artifacts".into()).unwrap();
        let mut rng = Rng::new(88);
        let mut out = Vec::new();
        for id in 0..6 {
            let rows = 65 + 13 * id as usize; // straddle word boundaries
            let a = random_words(&mut rng, rows, 7, Radix::TERNARY);
            let b = random_words(&mut rng, rows, 7, Radix::TERNARY);
            let res = svc
                .run(Job::new(id, OpKind::Add, Radix::TERNARY, true, a, b))
                .unwrap();
            out.push((res.values, res.stats));
        }
        svc.shutdown();
        out
    };
    assert_eq!(run(BackendKind::Native), run(BackendKind::NativeBitSliced));
}

/// Batcher invariants: `make_tiles` → `extract` round-trips the inputs,
/// padding is confined to the last tile and sums to
/// `tiles × tile_rows − rows`, including exact-multiple-of-tile
/// boundaries.
#[test]
fn batcher_tiling_roundtrip_property() {
    forall(Config::cases(120), |rng| {
        let radix = Radix::TERNARY;
        let p = 1 + rng.index(10);
        let tile_rows = 1 + rng.index(64);
        // bias toward exact multiples of the tile height
        let rows = if rng.chance(0.3) {
            tile_rows * (1 + rng.index(4))
        } else {
            1 + rng.index(300)
        };
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);
        let tiles = make_tiles(&a, &b, tile_rows);
        assert_eq!(tiles.len(), (rows + tile_rows - 1) / tile_rows);

        // round-trip: extracting from the tile's own data returns the B
        // operands and zero carries, in global row order
        let mut out = Vec::new();
        for t in &tiles {
            out.extend(t.extract(&t.data, radix));
        }
        assert_eq!(out.len(), rows);
        for (r, (w, c)) in out.iter().enumerate() {
            assert_eq!(w, &b[r], "row {r} (rows={rows} tile={tile_rows})");
            assert_eq!(*c, 0);
        }
        // the A operands are preserved row-major too
        for (t_idx, t) in tiles.iter().enumerate() {
            let cols = t.layout.cols();
            for r in 0..t.live_rows {
                let g = t_idx * tile_rows + r;
                assert_eq!(&t.data[r * cols..r * cols + p], a[g].digits());
            }
        }
        // padding accounting
        let pad: usize = tiles.iter().map(|t| t.pad_rows()).sum();
        assert_eq!(pad, tiles.len() * tile_rows - rows);
        for t in &tiles[..tiles.len() - 1] {
            assert_eq!(t.pad_rows(), 0, "only the last tile may pad");
        }
        if rows % tile_rows == 0 {
            assert_eq!(pad, 0, "exact multiples must not pad");
        }
    });
}

/// `strip_padding` never underflows a histogram class: every corrected
/// count stays ≤ its original (saturating at zero), for any pad count and
/// any class list — including out-of-range classes, which are ignored.
#[test]
fn strip_padding_never_underflows() {
    forall(Config::cases(200), |rng| {
        let len = 1 + rng.index(6);
        let orig: Vec<u64> = (0..len).map(|_| rng.below(25)).collect();
        let mut hist = orig.clone();
        let pad = rng.below(40); // often larger than any class count
        let classes: Vec<usize> = (0..rng.index(8)).map(|_| rng.index(len + 2)).collect();
        strip_padding(&mut hist, pad, &classes);
        for (k, (&now, &was)) in hist.iter().zip(&orig).enumerate() {
            assert!(now <= was, "class {k} grew: {was} -> {now}");
        }
    });
}

/// `pad_classes` covers every pass, and for the arithmetic LUT family the
/// all-zero padding row always mismatches ≥ 1 cell (000… is noAction).
#[test]
fn pad_classes_match_lut_shape() {
    use mvap::ap::{adder_lut, mac_lut, sub_lut, ExecMode};
    for lut in [
        adder_lut(Radix::TERNARY, ExecMode::Blocked),
        adder_lut(Radix::BINARY, ExecMode::NonBlocked),
        sub_lut(Radix::TERNARY, ExecMode::Blocked),
        mac_lut(Radix::TERNARY, ExecMode::NonBlocked),
    ] {
        let classes = pad_classes(&lut);
        assert_eq!(classes.len(), lut.passes.len(), "{}", lut.name);
        assert!(classes.iter().all(|&k| (1..=lut.arity).contains(&k)), "{}", lut.name);
    }
}

/// THE coalescing acceptance property: for random mixed batches (several
/// signatures, random rows/ops/radices/modes), per-job values, stats,
/// energy, and delay from the coalesced path equal the solo path — on
/// both storage backends.
#[test]
fn coalesced_batches_are_value_and_stats_exact() {
    use mvap::cam::StorageKind;
    forall(Config::cases(10), |rng| {
        let kind = if rng.chance(0.5) { StorageKind::Scalar } else { StorageKind::BitSliced };
        // a few signatures, many small jobs spread across them
        let nsigs = 1 + rng.index(3);
        let sigs: Vec<(OpKind, Radix, bool, usize)> = (0..nsigs)
            .map(|_| {
                let op = [OpKind::Add, OpKind::Sub, OpKind::Mac][rng.index(3)];
                let radix = if rng.chance(0.3) { Radix::BINARY } else { Radix::TERNARY };
                (op, radix, rng.chance(0.5), 1 + rng.index(6))
            })
            .collect();
        let njobs = 3 + rng.index(9);
        let jobs: Vec<Job> = (0..njobs)
            .map(|id| {
                let (op, radix, blocked, p) = sigs[rng.index(nsigs)];
                let rows = 1 + rng.index(120);
                let a = random_words(rng, rows, p, radix);
                let b = random_words(rng, rows, p, radix);
                Job::new(id as u64, op, radix, blocked, a, b)
            })
            .collect();

        // solo reference
        let mut solo = VectorEngine::new(Box::new(NativeBackend::new(kind)));
        let want: Vec<_> = jobs.iter().map(|j| solo.execute(j).unwrap()).collect();

        // coalesced: group by signature as the service front door does
        let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
        let mut order: Vec<Vec<usize>> = Vec::new();
        let mut seen: Vec<JobSignature> = Vec::new();
        for (i, j) in jobs.iter().enumerate() {
            let sig = JobSignature::of(j);
            match seen.iter().position(|s| *s == sig) {
                Some(g) => order[g].push(i),
                None => {
                    seen.push(sig);
                    order.push(vec![i]);
                }
            }
        }
        for idxs in order {
            let group: Vec<Job> = idxs.iter().map(|&i| jobs[i].clone()).collect();
            let got = eng.execute_coalesced(&group).unwrap();
            for (res, &i) in got.iter().zip(&idxs) {
                let w = &want[i];
                assert_eq!(res.id, w.id);
                assert_eq!(res.values, w.values, "job {i} values ({kind:?})");
                assert_eq!(res.stats, w.stats, "job {i} stats ({kind:?})");
                assert_eq!(res.energy, w.energy, "job {i} energy");
                assert_eq!(res.delay_cycles, w.delay_cycles, "job {i} delay");
            }
        }
        assert_eq!(eng.metrics().jobs, njobs as u64);
        // coalescing never dispatches more tile capacity than solo
        assert!(eng.metrics().tile_capacity_rows <= solo.metrics().tile_capacity_rows);
        assert!(eng.metrics().fill_rate() >= solo.metrics().fill_rate());
    });
}

/// The sharded, cross-submission coalescing service returns exact results
/// for a mixed workload and accounts for every job exactly once.
#[test]
fn sharded_service_end_to_end_mixed_workload() {
    let cfg = ShardConfig {
        shards: 3,
        queue_depth: 32,
        flush_after: std::time::Duration::from_millis(1),
        ..ShardConfig::default()
    };
    let svc = ShardedService::start(cfg, || {
        Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
    })
    .unwrap();
    let mut rng = Rng::new(404);
    let mut jobs = Vec::new();
    let mut oracle = Vec::new();
    for id in 0..24u64 {
        let radix = if id % 3 == 0 { Radix::BINARY } else { Radix::TERNARY };
        let op = match id % 3 {
            0 => OpKind::Add,
            1 => OpKind::Sub,
            _ => OpKind::Mac,
        };
        let p = 1 + (id as usize % 5);
        let rows = 1 + rng.index(200);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        jobs.push(Job::new(id, op, radix, id % 2 == 0, a.clone(), b.clone()));
        oracle.push((op, radix, a, b));
    }
    let results = svc.run_many(jobs).unwrap();
    for (id, res) in results.iter().enumerate() {
        let (op, radix, a, b) = &oracle[id];
        assert_eq!(res.id, id as u64);
        let n = radix.n() as u16;
        for r in 0..a.len() {
            let expect: Vec<u8> = match op {
                OpKind::Add => a[r].add_ref(&b[r], 0).0.digits().to_vec(),
                OpKind::Sub => a[r].sub_ref(&b[r], 0).0.digits().to_vec(),
                OpKind::Mac => {
                    let mut carry = 0u16;
                    a[r].digits()
                        .iter()
                        .zip(b[r].digits())
                        .map(|(&x, &y)| {
                            let v = x as u16 * y as u16 + carry;
                            carry = v / n;
                            (v % n) as u8
                        })
                        .collect()
                }
                OpKind::Reduce => unreachable!("this sweep submits element-wise ops only"),
            };
            assert_eq!(res.values[r].0.digits(), &expect[..], "job {id} row {r} {op:?}");
        }
    }
    let (agg, per_shard) = svc.shutdown();
    assert_eq!(agg.jobs, 24);
    assert_eq!(agg.solo_jobs + agg.coalesced_jobs, 24);
    assert_eq!(per_shard.iter().map(|m| m.jobs).sum::<u64>(), 24);
}

/// Energy model cross-check at the Table XI design point: the ternary AP
/// consumes ~12% less total energy than the equivalent binary AP.
#[test]
fn ternary_beats_binary_energy() {
    let mut rng = Rng::new(11);
    let rows = 2000;
    let run = |radix: Radix, p: usize, rng: &mut Rng| {
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);
        let mut eng = mvap::coordinator::VectorEngine::new(Box::new(NativeBackend::default()));
        let res = eng
            .execute(&Job::new(1, OpKind::Add, radix, false, a, b))
            .unwrap();
        res.energy.total() / rows as f64
    };
    let binary = run(Radix::BINARY, 32, &mut rng);
    let ternary = run(Radix::TERNARY, 20, &mut rng);
    let saving = 1.0 - ternary / binary;
    assert!(
        (0.08..=0.17).contains(&saving),
        "ternary energy saving {saving:.3} outside the Table XI band (12.25%)"
    );
}

/// LUT generation → simulation soundness for a randomly chosen function
/// (random truth tables with the in-place structure).
#[test]
fn random_function_luts_are_sound() {
    use mvap::diagram::StateDiagram;
    use mvap::func::TruthTable;
    use mvap::lutgen::{generate_blocked, generate_non_blocked, validate_lut};
    forall(Config::cases(60), |rng| {
        let n = 2 + rng.digit(3); // radix 2..4
        let radix = mvap::mvl::Radix(n);
        // random f over (A, B): keep A, write f(A,B)
        let mut outputs = vec![0u8; (n as usize).pow(2)];
        for o in outputs.iter_mut() {
            *o = rng.digit(n);
        }
        let table = TruthTable::from_fn("rand", radix, 2, 1, |s| {
            let idx = s[0] as usize * n as usize + s[1] as usize;
            vec![s[0], outputs[idx]]
        });
        match StateDiagram::build(table) {
            Ok(d) => {
                let nb = generate_non_blocked(&d);
                assert!(validate_lut(&nb, d.table()).is_empty(), "non-blocked unsound");
                let b = generate_blocked(&d);
                assert!(validate_lut(&b, d.table()).is_empty(), "blocked unsound");
            }
            Err(e) => {
                // Some functions are not implementable in-place: ones with
                // no fixed point (e.g. involutions like NOT), or cycles
                // whose every alternate output also avoids the roots.
                // These must be *reported*, never mis-generated.
                let msg = format!("{e}");
                assert!(
                    msg.contains("alternate output") || msg.contains("no fixed point"),
                    "unexpected error {msg}"
                );
            }
        }
    });
}
