//! Cross-module integration tests that need no AOT artifacts: LUT
//! generation → AP simulation → coordinator service, plus property tests
//! on coordinator invariants.

use mvap::coordinator::{EngineService, Job, NativeBackend, OpKind};
use mvap::coordinator::Backend;
use mvap::mvl::{Radix, Word};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

fn random_words(rng: &mut Rng, rows: usize, p: usize, radix: Radix) -> Vec<Word> {
    (0..rows)
        .map(|_| Word::from_digits(rng.number(p, radix.n()), radix))
        .collect()
}

/// End-to-end through the threaded service: many concurrent jobs, several
/// ops and radices, all results exact.
#[test]
fn service_end_to_end_mixed_workload() {
    let svc = EngineService::start(4, 16, || {
        Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
    })
    .unwrap();
    let mut rng = Rng::new(404);
    let mut pending = Vec::new();
    for id in 0..24 {
        let radix = if id % 3 == 0 { Radix::BINARY } else { Radix::TERNARY };
        let op = match id % 3 {
            0 => OpKind::Add,
            1 => OpKind::Sub,
            _ => OpKind::Mac,
        };
        let p = 1 + (id as usize % 10);
        let rows = 1 + rng.index(300);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let job = Job::new(id, op, radix, id % 2 == 0, a.clone(), b.clone());
        pending.push((svc.submit(job), op, radix, a, b, id));
    }
    for (rx, op, radix, a, b, id) in pending {
        let res = rx.recv().unwrap().unwrap();
        assert_eq!(res.id, id);
        let n = radix.n() as u16;
        for r in 0..a.len() {
            let expect: Vec<u8> = match op {
                OpKind::Add => a[r].add_ref(&b[r], 0).0.digits().to_vec(),
                OpKind::Sub => a[r].sub_ref(&b[r], 0).0.digits().to_vec(),
                OpKind::Mac => {
                    let mut carry = 0u16;
                    a[r].digits()
                        .iter()
                        .zip(b[r].digits())
                        .map(|(&x, &y)| {
                            let v = x as u16 * y as u16 + carry;
                            carry = v / n;
                            (v % n) as u8
                        })
                        .collect()
                }
            };
            assert_eq!(res.values[r].0.digits(), &expect[..], "job {id} row {r} {op:?}");
        }
    }
    let metrics = svc.shutdown();
    assert_eq!(metrics.jobs, 24);
}

/// Coordinator invariant: results are independent of tile size (padding
/// and splitting must not change values or live-row stats).
#[test]
fn tiling_invariance_property() {
    forall(Config::cases(20), |rng| {
        let radix = Radix::TERNARY;
        let p = 1 + rng.index(8);
        let rows = 1 + rng.index(600);
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);

        // Direct single-array reference (no tiling).
        use mvap::ap::{add_vectors, adder_lut, load_operands, Ap, ExecMode};
        let lut = adder_lut(radix, ExecMode::Blocked);
        let (array, layout) = load_operands(radix, &a, &b, None);
        let mut ap = Ap::new(array);
        let want = add_vectors(&mut ap, &layout, &lut, ExecMode::Blocked);
        let want_stats = ap.take_stats();

        // Coordinator path (DEFAULT_TILE_ROWS tiling + padding).
        let mut eng = mvap::coordinator::VectorEngine::new(Box::new(NativeBackend::default()));
        let job = Job::new(1, OpKind::Add, radix, true, a, b);
        let got = eng.execute(&job).unwrap();

        assert_eq!(got.values, want, "values differ under tiling");
        // live-row event counts match exactly after padding strip
        assert_eq!(
            got.stats.row_compares(),
            want_stats.row_compares(),
            "row compares (rows={rows} p={p})"
        );
        assert_eq!(got.stats.mismatch_hist, want_stats.mismatch_hist);
        assert_eq!(got.stats.sets, want_stats.sets);
    });
}

/// The threaded service over the bit-sliced backend kind produces the
/// same results as the scalar-native service.
#[test]
fn bitsliced_service_matches_native() {
    use mvap::coordinator::BackendKind;
    let run = |kind: BackendKind| {
        let svc = EngineService::start_kind(2, 4, kind, "artifacts".into()).unwrap();
        let mut rng = Rng::new(88);
        let mut out = Vec::new();
        for id in 0..6 {
            let rows = 65 + 13 * id as usize; // straddle word boundaries
            let a = random_words(&mut rng, rows, 7, Radix::TERNARY);
            let b = random_words(&mut rng, rows, 7, Radix::TERNARY);
            let res = svc
                .run(Job::new(id, OpKind::Add, Radix::TERNARY, true, a, b))
                .unwrap();
            out.push((res.values, res.stats));
        }
        svc.shutdown();
        out
    };
    assert_eq!(run(BackendKind::Native), run(BackendKind::NativeBitSliced));
}

/// Energy model cross-check at the Table XI design point: the ternary AP
/// consumes ~12% less total energy than the equivalent binary AP.
#[test]
fn ternary_beats_binary_energy() {
    let mut rng = Rng::new(11);
    let rows = 2000;
    let run = |radix: Radix, p: usize, rng: &mut Rng| {
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);
        let mut eng = mvap::coordinator::VectorEngine::new(Box::new(NativeBackend::default()));
        let res = eng
            .execute(&Job::new(1, OpKind::Add, radix, false, a, b))
            .unwrap();
        res.energy.total() / rows as f64
    };
    let binary = run(Radix::BINARY, 32, &mut rng);
    let ternary = run(Radix::TERNARY, 20, &mut rng);
    let saving = 1.0 - ternary / binary;
    assert!(
        (0.08..=0.17).contains(&saving),
        "ternary energy saving {saving:.3} outside the Table XI band (12.25%)"
    );
}

/// LUT generation → simulation soundness for a randomly chosen function
/// (random truth tables with the in-place structure).
#[test]
fn random_function_luts_are_sound() {
    use mvap::diagram::StateDiagram;
    use mvap::func::TruthTable;
    use mvap::lutgen::{generate_blocked, generate_non_blocked, validate_lut};
    forall(Config::cases(60), |rng| {
        let n = 2 + rng.digit(3); // radix 2..4
        let radix = mvap::mvl::Radix(n);
        // random f over (A, B): keep A, write f(A,B)
        let mut outputs = vec![0u8; (n as usize).pow(2)];
        for o in outputs.iter_mut() {
            *o = rng.digit(n);
        }
        let table = TruthTable::from_fn("rand", radix, 2, 1, |s| {
            let idx = s[0] as usize * n as usize + s[1] as usize;
            vec![s[0], outputs[idx]]
        });
        match StateDiagram::build(table) {
            Ok(d) => {
                let nb = generate_non_blocked(&d);
                assert!(validate_lut(&nb, d.table()).is_empty(), "non-blocked unsound");
                let b = generate_blocked(&d);
                assert!(validate_lut(&b, d.table()).is_empty(), "blocked unsound");
            }
            Err(e) => {
                // Some functions are not implementable in-place: ones with
                // no fixed point (e.g. involutions like NOT), or cycles
                // whose every alternate output also avoids the roots.
                // These must be *reported*, never mis-generated.
                let msg = format!("{e}");
                assert!(
                    msg.contains("alternate output") || msg.contains("no fixed point"),
                    "unexpected error {msg}"
                );
            }
        }
    });
}
