//! Differential property tests for the in-engine segmented tree
//! reduction (`OpKind::Reduce`): the bit-sliced plane-native path must be
//! observably identical to the scalar path — values, per-segment
//! statistics, and summaries — and both must match an integer reference,
//! for radices 2–5, row counts straddling 64-row word boundaries, and
//! segment cuts landing mid-word.
//!
//! Replay a failing case with `MVAP_PROP_SEED=0x… cargo test -q --test
//! reduce_differential` (the seed is printed in the failure message);
//! ci.sh runs a fixed-seed pass of exactly this suite as its
//! reproduction stage.

use mvap::ap::{
    adder_lut, extract_reduced, fold_rounds, load_reduce_operands, reduce_vectors, Ap, ApStats,
    ExecMode, LutKernel,
};
use mvap::cam::StorageKind;
use mvap::coordinator::{Job, NativeBackend, VectorEngine};
use mvap::mvl::{Radix, Word};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

mod common;

use common::{boundary_rows, random_words};

/// Random strictly-increasing segment bounds over `rows` rows; cuts are
/// uniform, so they routinely land mid-word.
fn random_segments(rng: &mut Rng, rows: usize) -> Vec<usize> {
    let mut bounds = Vec::new();
    let mut at = 0usize;
    while at < rows {
        at += 1 + rng.index(rows - at);
        bounds.push(at);
    }
    bounds
}

/// Integer reference: per-segment sums mod radix^p.
fn reference_sums(values: &[Word], bounds: &[usize], radix: Radix, p: usize) -> Vec<u128> {
    let modulus = (radix.n() as u128).pow(p as u32);
    let mut out = Vec::with_capacity(bounds.len());
    let mut start = 0usize;
    for &end in bounds {
        out.push(values[start..end].iter().map(|w| w.to_u128()).sum::<u128>() % modulus);
        start = end;
    }
    out
}

/// The core differential: scalar vs bit-sliced `reduce_vectors` agree on
/// values, per-segment stats, aggregate stats, and summary; values match
/// the integer reference; rounds == ⌈log₂ max-segment⌉.
#[test]
fn reduce_scalar_vs_bitsliced_differential() {
    forall(Config::cases(60), |rng| {
        let radix = Radix(2 + rng.digit(4)); // 2..=5
        let p = 2 + rng.index(7);
        let rows = boundary_rows(rng);
        let values = random_words(rng, rows, p, radix);
        let seg_bounds = random_segments(rng, rows);
        let mode = if rng.chance(0.5) { ExecMode::Blocked } else { ExecMode::NonBlocked };
        let lut = adder_lut(radix, mode);
        let kernel = LutKernel::compile(&lut, mode);
        let expect = reference_sums(&values, &seg_bounds, radix, p);
        let want_rounds = {
            let mut start = 0usize;
            let mut r = 0u32;
            for &end in &seg_bounds {
                r = r.max(fold_rounds(end - start));
                start = end;
            }
            r as u64
        };

        let mut runs = Vec::new();
        for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
            let (storage, layout) = load_reduce_operands(kind, radix, &values);
            let mut ap = Ap::with_storage(storage);
            let (stats, summary) =
                reduce_vectors(&mut ap, &layout, &lut, mode, &kernel, &seg_bounds, &seg_bounds);
            let results = extract_reduced(ap.storage(), &layout, &seg_bounds);
            for (s, r) in results.iter().enumerate() {
                assert_eq!(r.0.to_u128(), expect[s], "segment {s} value ({kind:?})");
            }
            assert_eq!(summary.rounds, want_rounds, "{kind:?}");
            runs.push((results, stats, ap.take_stats(), summary, ap.storage().to_digits()));
        }
        let (v1, s1, agg1, sum1, d1) = &runs[0];
        let (v2, s2, agg2, sum2, d2) = &runs[1];
        assert_eq!(v1, v2, "values diverged");
        assert_eq!(s1, s2, "per-segment stats diverged");
        assert_eq!(agg1, agg2, "aggregate stats diverged");
        assert_eq!(sum1, sum2, "summaries diverged");
        assert_eq!(d1, d2, "final array contents diverged");
        // per-segment stats sum to the aggregate's data-dependent events
        assert!(
            ApStats::sum_of(s1).same_events(agg1),
            "segment stats must sum to the aggregate"
        );
    });
}

/// Per-segment stats equal a solo reduction of exactly that segment's
/// operands — the attribution exactness the coalescing path relies on.
#[test]
fn reduce_segment_stats_match_isolated_runs() {
    forall(Config::cases(30), |rng| {
        let radix = Radix(2 + rng.digit(4));
        let p = 2 + rng.index(5);
        let rows = 2 + rng.index(150);
        let values = random_words(rng, rows, p, radix);
        let seg_bounds = random_segments(rng, rows);
        let mode = if rng.chance(0.5) { ExecMode::Blocked } else { ExecMode::NonBlocked };
        let lut = adder_lut(radix, mode);
        let kernel = LutKernel::compile(&lut, mode);
        let kind =
            if rng.chance(0.5) { StorageKind::Scalar } else { StorageKind::BitSliced };
        // Rounds are lockstep across segments, so a segment equals its
        // solo run exactly when its own round count is the batch maximum
        // (smaller segments sit as noAction rows for the extra rounds and
        // legitimately record more compare events than solo) — compare
        // those segments only. This is the same invariant the coalescing
        // signature enforces across jobs via `fold_rounds`.
        let (storage, layout) = load_reduce_operands(kind, radix, &values);
        let mut ap = Ap::with_storage(storage);
        let (stats, summary) =
            reduce_vectors(&mut ap, &layout, &lut, mode, &kernel, &seg_bounds, &seg_bounds);
        let mut start = 0usize;
        for (s, &end) in seg_bounds.iter().enumerate() {
            if fold_rounds(end - start) as u64 == summary.rounds {
                let sub = values[start..end].to_vec();
                let (storage, layout) = load_reduce_operands(kind, radix, &sub);
                let mut solo = Ap::with_storage(storage);
                let (solo_stats, solo_summary) = reduce_vectors(
                    &mut solo,
                    &layout,
                    &lut,
                    mode,
                    &kernel,
                    &[sub.len()],
                    &[sub.len()],
                );
                assert_eq!(solo_summary.rounds, summary.rounds);
                assert_eq!(
                    &stats[s], &solo_stats[0],
                    "segment {s} ({start}..{end}) of {rows} rows ({kind:?})"
                );
            }
            start = end;
        }
    });
}

/// Engine-level differential: `Job::reduce` through `VectorEngine` on
/// both backends — identical values, stats, energy; coalesced batches of
/// same-signature reduce jobs are exact against solo execution.
#[test]
fn reduce_jobs_differential_through_engine() {
    forall(Config::cases(15), |rng| {
        let radix = Radix(2 + rng.digit(3)); // 2..=4
        let p = 2 + rng.index(5);
        let blocked = rng.chance(0.5);
        let rows = 1 + rng.index(120);
        let njobs = 1 + rng.index(4);
        let jobs: Vec<Job> = (0..njobs)
            .map(|id| {
                let values = random_words(rng, rows, p, radix);
                let segments = random_segments(rng, rows);
                Job::reduce(id as u64, radix, blocked, values, segments)
            })
            .collect();
        // identical row counts do NOT imply identical signatures — the
        // segment structure sets the rounds — so restrict the coalesced
        // comparison to jobs sharing the first job's signature
        let sig = jobs[0].signature();
        let batch: Vec<Job> =
            jobs.iter().filter(|j| j.signature() == sig).cloned().collect();

        let mut per_backend = Vec::new();
        for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
            let mut solo = VectorEngine::new(Box::new(NativeBackend::new(kind)));
            let want: Vec<_> = batch.iter().map(|j| solo.execute(j).unwrap()).collect();
            let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
            let got = eng.execute_coalesced(&batch).unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.values, w.values, "job {} ({kind:?})", g.id);
                assert_eq!(g.stats, w.stats, "job {} ({kind:?})", g.id);
                assert_eq!(g.energy, w.energy);
                assert_eq!(g.delay_cycles, w.delay_cycles);
            }
            // reference values
            for (job, res) in batch.iter().zip(&got) {
                let expect = reference_sums(&job.a, job.segments(), radix, p);
                assert_eq!(res.values.len(), job.segments().len());
                for (s, &e) in expect.iter().enumerate() {
                    assert_eq!(res.values[s].0.to_u128(), e, "job {} seg {s}", job.id);
                }
            }
            per_backend.push(got);
        }
        // cross-backend parity of the coalesced results
        for (g1, g2) in per_backend[0].iter().zip(&per_backend[1]) {
            assert_eq!(g1.values, g2.values);
            assert_eq!(g1.stats, g2.stats);
            assert_eq!(g1.energy, g2.energy);
        }
    });
}

/// Radix-2 ⇄ the binary AP: reduction works on the binary adder LUT too,
/// across word-boundary row counts.
#[test]
fn reduce_binary_word_boundaries() {
    for rows in [63usize, 64, 65, 128, 129] {
        let radix = Radix::BINARY;
        let p = 12; // the reference reduces mod 2^12, like the fold
        let mut rng = Rng::new(rows as u64);
        let values = random_words(&mut rng, rows, p, radix);
        let lut = adder_lut(radix, ExecMode::Blocked);
        let kernel = LutKernel::compile(&lut, ExecMode::Blocked);
        let expect = reference_sums(&values, &[rows], radix, p);
        for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
            let (storage, layout) = load_reduce_operands(kind, radix, &values);
            let mut ap = Ap::with_storage(storage);
            let (_, summary) = reduce_vectors(
                &mut ap,
                &layout,
                &lut,
                ExecMode::Blocked,
                &kernel,
                &[rows],
                &[rows],
            );
            let out = extract_reduced(ap.storage(), &layout, &[rows]);
            assert_eq!(out[0].0.to_u128(), expect[0], "rows={rows} {kind:?}");
            assert_eq!(summary.rounds, fold_rounds(rows) as u64);
            assert_eq!(summary.rows_moved, (rows - 1) as u64);
        }
    }
}
