//! End-to-end tests for the structured-tracing layer: the span chains
//! recorded across the front door, shard coordinator, and engine must be
//! complete for every sampled request, head sampling must keep whole
//! causal chains (never fragments), the Chrome exporter must stay
//! well-formed even over partial (dropped-span) traces, and the modeled
//! energy attributed to spans must reconcile exactly with the engine
//! metrics — two independent accountings of the same physics model.

use mvap::coordinator::{Backend, EngineService, Job, NativeBackend, OpKind, ShardConfig};
use mvap::mvl::{Radix, Word};
use mvap::program::{builtin, BoundProgram};
use mvap::serving::{FrontConfig, FrontDoor};
use mvap::telemetry::{chrome_trace, Flow, SpanEvent, SpanKind, SpanRecorder, TraceData};
use mvap::telemetry::PROGRAM_REQ_BIT;
use mvap::util::Rng;
use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Duration;

fn native() -> anyhow::Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
}

fn random_words(rng: &mut Rng, rows: usize, digits: usize, radix: Radix) -> Vec<Word> {
    (0..rows)
        .map(|_| Word::from_digits(rng.number(digits, radix.n()), radix))
        .collect()
}

fn front(recorder: &Arc<SpanRecorder>) -> FrontDoor {
    FrontDoor::start_traced(
        FrontConfig {
            max_in_flight: 64,
            shard: ShardConfig {
                shards: 2,
                queue_depth: 16,
                flush_after: Duration::from_micros(200),
                ..ShardConfig::default()
            },
        },
        Some(Arc::clone(recorder)),
        native,
    )
    .expect("front door starts")
}

/// The request ids of every event matching a predicate.
fn reqs_where(data: &TraceData, pred: impl Fn(&SpanEvent) -> bool) -> BTreeSet<u64> {
    data.events.iter().filter(|e| pred(e)).map(|e| e.req).collect()
}

/// Every request traced at sample 1 leaves a full admit → job → reply
/// chain, the program leaves a synthetic-request chain, and the span
/// energy reconciles with the aggregate metrics to 1e-9 relative.
#[test]
fn traced_front_door_chains_are_complete_and_energy_reconciles() {
    let radix = Radix::TERNARY;
    let recorder = SpanRecorder::new(1);
    let front = front(&recorder);
    let mut rng = Rng::new(0x7e1e);
    let mut replies = Vec::new();
    for id in 1..=10u64 {
        let a = random_words(&mut rng, 16, 4, radix);
        let b = random_words(&mut rng, 16, 4, radix);
        let job = Job::new(id, OpKind::Add, radix, true, a, b);
        replies.push(front.submit(job).unwrap());
    }
    let plan = Arc::new(builtin::dot(radix, 4).plan());
    let inputs: Vec<(&str, Vec<Word>)> = plan
        .program()
        .input_names()
        .iter()
        .map(|n| (*n, random_words(&mut rng, 16, 4, radix)))
        .collect();
    let bound = BoundProgram::bind(&plan, inputs, true).unwrap();
    let prog_rx = front.submit_program(bound).unwrap();
    for rx in replies {
        rx.recv().unwrap().unwrap();
    }
    prog_rx.recv().unwrap().unwrap();
    assert!(front.drain(Duration::from_secs(10)), "front door failed to drain");
    let (_, agg, _) = front.shutdown();

    let data = recorder.drain();
    assert_eq!(data.dropped, 0, "nothing should drop at this volume");

    let admits = reqs_where(&data, |e| e.kind == SpanKind::Admit);
    let finished = reqs_where(&data, |e| e.kind == SpanKind::Reply && e.flow == Flow::Finish);
    assert_eq!(admits, finished, "every admitted request must finish its flow");
    assert_eq!(admits.len(), 11, "10 jobs + 1 program");
    assert!(
        admits.iter().any(|r| r & PROGRAM_REQ_BIT != 0),
        "the program's synthetic request id must carry the marker bit"
    );
    for id in 1..=10u64 {
        assert!(
            data.events.iter().any(|e| e.kind == SpanKind::Job && e.req == id),
            "request {id} lost its job attribution span"
        );
    }

    let span_energy: f64 = data.events.iter().filter_map(|e| e.request_energy_j()).sum();
    let rel = (span_energy - agg.modeled_energy_j).abs() / agg.modeled_energy_j.abs().max(1e-30);
    assert!(
        rel < 1e-9,
        "span energy {span_energy:e} J vs metrics {:e} J (rel {rel:e})",
        agg.modeled_energy_j
    );

    // The exporter stays balanced over the real (multi-lane) trace.
    let json = chrome_trace(&data, &[]);
    let sync_b = json.matches("\"ph\":\"B\"").count();
    let sync_e = json.matches("\"ph\":\"E\"").count();
    assert_eq!(sync_b, sync_e, "sync B/E pairs unbalanced");
    let async_b = json.matches("\"ph\":\"b\"").count();
    let async_e = json.matches("\"ph\":\"e\"").count();
    assert_eq!(async_b, async_e, "async b/e pairs unbalanced");
    assert_eq!(json.matches("\"ph\":\"s\"").count(), 11, "one flow start per request");
    assert_eq!(json.matches("\"ph\":\"f\"").count(), 11, "one flow finish per request");
}

/// Head sampling keeps whole chains: with 1-in-4 sampling, exactly the
/// deterministically sampled request ids get admit spans and flow
/// finishes, and each sampled id keeps its job span. Unsampled ids never
/// open a flow (batch-mates of a sampled request may still leave
/// execution spans — the causal chain is kept intact by design).
#[test]
fn head_sampling_keeps_whole_chains() {
    let radix = Radix::TERNARY;
    let recorder = SpanRecorder::new(4);
    let ids: Vec<u64> = (1..=32).collect();
    let mut expected = BTreeSet::new();
    for &id in &ids {
        if recorder.sampled(id) {
            expected.insert(id);
        }
    }
    assert!(
        !expected.is_empty() && expected.len() < ids.len(),
        "sampler should split 32 ids: kept {}",
        expected.len()
    );

    let front = front(&recorder);
    let mut rng = Rng::new(77);
    let mut replies = Vec::new();
    for &id in &ids {
        let a = random_words(&mut rng, 16, 4, radix);
        let b = random_words(&mut rng, 16, 4, radix);
        replies.push(front.submit(Job::new(id, OpKind::Add, radix, true, a, b)).unwrap());
    }
    for rx in replies {
        rx.recv().unwrap().unwrap();
    }
    assert!(front.drain(Duration::from_secs(10)), "front door failed to drain");
    front.shutdown();

    let data = recorder.drain();
    let admits = reqs_where(&data, |e| e.kind == SpanKind::Admit);
    assert_eq!(admits, expected, "admit spans must cover exactly the sampled ids");
    let finished = reqs_where(&data, |e| e.flow == Flow::Finish);
    assert_eq!(finished, expected, "flow finishes must cover exactly the sampled ids");
    for &id in &expected {
        assert!(
            data.events.iter().any(|e| e.kind == SpanKind::Job && e.req == id),
            "sampled request {id} lost its job span"
        );
    }
    assert!(
        data.events.iter().all(|e| e.flow == Flow::None || expected.contains(&e.req)),
        "an unsampled request opened or finished a flow"
    );
}

/// Step reports carry span ids when traced and zeros when not.
#[test]
fn step_reports_carry_span_ids_only_when_traced() {
    let radix = Radix::TERNARY;
    let mut rng = Rng::new(5);
    let plan = Arc::new(builtin::fir(radix, 4, 4).plan());
    let mut run = |recorder: Option<Arc<SpanRecorder>>| {
        let inputs: Vec<(&str, Vec<Word>)> = plan
            .program()
            .input_names()
            .iter()
            .map(|n| (*n, random_words(&mut rng, 16, 4, radix)))
            .collect();
        let bound = BoundProgram::bind(&plan, inputs, true).unwrap();
        let svc = EngineService::start_traced(1, 4, recorder, native).unwrap();
        let report = svc.run_program(bound).unwrap();
        svc.shutdown();
        report
    };

    let untraced = run(None);
    assert!(untraced.steps.iter().all(|s| s.span == 0), "untraced steps must carry 0");

    let recorder = SpanRecorder::new(1);
    let traced = run(Some(Arc::clone(&recorder)));
    assert!(!traced.steps.is_empty());
    assert!(traced.steps.iter().all(|s| s.span != 0), "traced steps must carry span ids");
    let data = recorder.drain();
    let step_ids: BTreeSet<u64> =
        data.events.iter().filter(|e| e.kind == SpanKind::Step).map(|e| e.id).collect();
    for s in &traced.steps {
        assert!(step_ids.contains(&s.span), "step span {:#x} not in the trace", s.span);
    }
}

/// Tiny ring buffers overflow under load, but the loss is accounted
/// (dropped counter) and the exporter still emits a balanced document —
/// a partial trace degrades, never corrupts.
#[test]
fn overflow_drops_oldest_but_export_stays_balanced() {
    let radix = Radix::TERNARY;
    let recorder = SpanRecorder::with_capacity(1, 8);
    let svc = EngineService::start_traced(2, 8, Some(Arc::clone(&recorder)), native).unwrap();
    let mut rng = Rng::new(9);
    let mut replies = Vec::new();
    for id in 0..64u64 {
        let a = random_words(&mut rng, 8, 4, radix);
        let b = random_words(&mut rng, 8, 4, radix);
        replies.push(svc.submit(Job::new(id, OpKind::Add, radix, true, a, b)));
    }
    for rx in replies {
        rx.recv().unwrap().unwrap();
    }
    svc.shutdown();

    let data = recorder.drain();
    assert!(data.dropped > 0, "64 jobs through 8-slot sinks must drop spans");
    assert!(!data.events.is_empty(), "the newest spans survive");
    let json = chrome_trace(&data, &[]);
    let sync_b = json.matches("\"ph\":\"B\"").count();
    let sync_e = json.matches("\"ph\":\"E\"").count();
    assert_eq!(sync_b, sync_e, "partial traces must still balance");
    assert!(json.contains(&format!("\"droppedSpans\":{}", data.dropped)));
}
