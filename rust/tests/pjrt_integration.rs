//! Integration tests for the AOT path: artifacts → PJRT engines →
//! cross-check against the native functional simulator, element-exactly.
//!
//! These tests require `make artifacts`; they skip (with a note) when the
//! manifest is missing so `cargo test` stays green on a fresh checkout.

use mvap::coordinator::{Backend, Job, NativeBackend, OpKind, PjrtBackend, VectorEngine};
use mvap::mvl::{Radix, Word};
use mvap::util::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT integration test: run `make artifacts` first");
        None
    }
}

fn random_words(rng: &mut Rng, rows: usize, p: usize, radix: Radix) -> Vec<Word> {
    (0..rows)
        .map(|_| Word::from_digits(rng.number(p, radix.n()), radix))
        .collect()
}

/// Stats equality modulo `rows_written`, which the AOT engine does not
/// re-derive (it is not an energy/delay input — see EngineOutput docs).
fn assert_stats_match(got: &mvap::ap::ApStats, want: &mvap::ap::ApStats, ctx: &str) {
    assert_eq!(got.compare_cycles, want.compare_cycles, "{ctx}: compare_cycles");
    assert_eq!(got.write_cycles, want.write_cycles, "{ctx}: write_cycles");
    assert_eq!(got.sets, want.sets, "{ctx}: sets");
    assert_eq!(got.resets, want.resets, "{ctx}: resets");
    assert_eq!(got.mismatch_hist, want.mismatch_hist, "{ctx}: mismatch_hist");
}

/// The central three-layer check: the AOT-compiled XLA engine and the
/// native Rust simulator produce identical values AND identical energy
/// stats for the same workload.
#[test]
fn pjrt_matches_native_ternary_add() {
    let Some(dir) = artifacts_dir() else { return };
    let radix = Radix::TERNARY;
    let mut rng = Rng::new(2024);
    for &(rows, p, blocked) in &[(100usize, 20usize, true), (256, 20, false), (300, 20, true)] {
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let job = |id| Job::new(id, OpKind::Add, radix, blocked, a.clone(), b.clone());

        let mut native = VectorEngine::new(Box::new(NativeBackend::default()));
        let want = native.execute(&job(1)).unwrap();

        let pjrt_backend = PjrtBackend::new(&dir).expect("pjrt backend");
        let mut pjrt = VectorEngine::new(Box::new(pjrt_backend));
        let got = pjrt.execute(&job(2)).unwrap();

        assert_eq!(got.values, want.values, "values rows={rows} p={p} blocked={blocked}");
        assert_eq!(
            got.stats.mismatch_hist, want.stats.mismatch_hist,
            "mismatch hist rows={rows} p={p} blocked={blocked}"
        );
        assert_eq!(got.stats.sets, want.stats.sets, "sets");
        assert_eq!(got.stats.resets, want.stats.resets, "resets");
        assert_eq!(got.stats.compare_cycles, want.stats.compare_cycles);
        assert_eq!(got.stats.write_cycles, want.stats.write_cycles);
        // identical stats ⇒ identical modeled energy
        assert_eq!(got.energy, want.energy);
    }
}

#[test]
fn pjrt_matches_native_binary_add() {
    let Some(dir) = artifacts_dir() else { return };
    let radix = Radix::BINARY;
    let mut rng = Rng::new(7);
    let a = random_words(&mut rng, 200, 32, radix);
    let b = random_words(&mut rng, 200, 32, radix);
    let mk = |id, blocked| Job::new(id, OpKind::Add, radix, blocked, a.clone(), b.clone());
    for blocked in [false, true] {
        let mut native = VectorEngine::new(Box::new(NativeBackend::default()));
        let want = native.execute(&mk(1, blocked)).unwrap();
        let mut pjrt = VectorEngine::new(Box::new(PjrtBackend::new(&dir).unwrap()));
        let got = pjrt.execute(&mk(2, blocked)).unwrap();
        assert_eq!(got.values, want.values);
        assert_stats_match(&got.stats, &want.stats, &format!("binary blocked={blocked}"));
    }
}

#[test]
fn pjrt_sub_and_mac() {
    let Some(dir) = artifacts_dir() else { return };
    let radix = Radix::TERNARY;
    let mut rng = Rng::new(99);
    for (op, p) in [(OpKind::Sub, 20usize), (OpKind::Mac, 8)] {
        let a = random_words(&mut rng, 64, p, radix);
        let b = random_words(&mut rng, 64, p, radix);
        let mut native = VectorEngine::new(Box::new(NativeBackend::default()));
        let want = native
            .execute(&Job::new(1, op, radix, true, a.clone(), b.clone()))
            .unwrap();
        let mut pjrt = VectorEngine::new(Box::new(PjrtBackend::new(&dir).unwrap()));
        let got = pjrt.execute(&Job::new(2, op, radix, true, a, b)).unwrap();
        assert_eq!(got.values, want.values, "{op:?}");
        assert_stats_match(&got.stats, &want.stats, &format!("{op:?}"));
    }
}

/// Tile selection picks the 1024-row engine for large jobs.
#[test]
fn pjrt_large_job_uses_bigger_tile() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::new(&dir).unwrap();
    let rows = backend.preferred_rows(OpKind::Add, Radix::TERNARY, true, 20);
    assert_eq!(rows, Some(1024));
    let mut rng = Rng::new(1);
    let a = random_words(&mut rng, 1500, 20, Radix::TERNARY);
    let b = random_words(&mut rng, 1500, 20, Radix::TERNARY);
    let mut eng = VectorEngine::new(Box::new(backend));
    let res = eng
        .execute(&Job::new(1, OpKind::Add, Radix::TERNARY, true, a.clone(), b.clone()))
        .unwrap();
    assert_eq!(res.tiles, 2); // 1500 rows over 1024-row tiles
    for r in 0..1500 {
        let (expect, c) = a[r].add_ref(&b[r], 0);
        assert_eq!(res.values[r], (expect, c), "row {r}");
    }
}
