//! End-to-end serving-layer integration: the front door + load generator
//! driving real sharded engines through the public API, checking the
//! admission/completion accounting invariants the unit tests assert
//! per-component.

use mvap::coordinator::{Backend, NativeBackend, ShardConfig};
use mvap::serving::{loadgen, FrontConfig, LoadConfig, LoopMode, Mix};
use std::time::Duration;

fn native() -> anyhow::Result<Box<dyn Backend>> {
    Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
}

/// Closed loop over an even five-class mix: everything admitted
/// completes, the per-class histograms partition the total, and the
/// engine-side latency histogram saw exactly the completed requests.
#[test]
fn closed_loop_serves_the_full_mix_and_drains() {
    let cfg = LoadConfig {
        duration: Duration::from_millis(250),
        clients: 4,
        mix: Mix::parse("1:1:1:1:1").unwrap(),
        rows: 4,
        digits: 4,
        ..LoadConfig::default()
    };
    let front_cfg = FrontConfig {
        max_in_flight: 32,
        shard: ShardConfig {
            shards: 2,
            flush_after: Duration::from_micros(300),
            ..ShardConfig::default()
        },
    };
    let report = loadgen::run(LoopMode::Closed, front_cfg, native, &cfg).unwrap();
    assert!(report.completed > 0, "report: {report:?}");
    assert_eq!(report.completed, report.admitted, "admitted work always completes");
    assert_eq!(report.failed, 0, "the native backend serves every class");
    assert_eq!(report.total.count(), report.completed);
    let per_class: u64 = report.per_class.iter().map(|(_, h)| h.count()).sum();
    assert_eq!(per_class, report.total.count(), "classes partition the total");
    assert_eq!(report.engine.jobs, report.completed);
    assert_eq!(report.engine.latency.count(), report.completed);
    // quantiles are extractable and ordered on real data
    let p50 = report.total.quantile_ns(0.50).unwrap();
    let p99 = report.total.quantile_ns(0.99).unwrap();
    assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
}

/// Open loop against a 1-deep admission cap with parked flushes: the
/// pacer must shed (never queue unboundedly, never panic), admission
/// accounting must balance, and the drain still completes every
/// admitted request.
#[test]
fn open_loop_sheds_at_the_admission_cap_and_still_drains() {
    let cfg = LoadConfig {
        duration: Duration::from_millis(150),
        rps: 2000,
        mix: Mix::parse("1:0:0:0:0").unwrap(),
        rows: 4,
        digits: 4,
        ..LoadConfig::default()
    };
    let front_cfg = FrontConfig {
        max_in_flight: 1,
        shard: ShardConfig {
            shards: 1,
            // park admitted work in the shard's batch so the single
            // admission slot stays occupied and the pacer must shed
            flush_after: Duration::from_millis(50),
            ..ShardConfig::default()
        },
    };
    let report = loadgen::run(LoopMode::Open, front_cfg, native, &cfg).unwrap();
    assert!(report.offered > 10, "pacer barely ran: {report:?}");
    assert_eq!(report.admitted + report.shed, report.offered, "every offer accounted");
    assert!(report.shed > 0, "1-deep admission under 2000 rps must shed: {report:?}");
    assert_eq!(report.completed, report.admitted, "drain completes every admitted request");
}
