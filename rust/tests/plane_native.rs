//! Differential property tests for the plane-native LUT fast path (PR-3
//! tentpole): on *both* storage backends, for radices 2–5, row counts
//! straddling 64-row word boundaries, segment bounds landing mid-word,
//! and planted don't-cares (the fallback), the kernel-driven fast path —
//! plain and segment-attributed — must be **value- and stats-exact**
//! against the faithful pass-by-pass `apply_lut` execution, and against
//! the row-at-a-time reference implementation it replaced.

mod common;

use common::{boundary_rows as random_rows, random_radix, KINDS};
use mvap::ap::{Ap, ExecMode, KernelCache, LutKernel};
use mvap::cam::{CamStorage, StorageKind};
use mvap::diagram::StateDiagram;
use mvap::func::{full_add, full_sub, mac_digit};
use mvap::lutgen::{generate_blocked, generate_non_blocked, Lut};
use mvap::mvl::{Radix, DONT_CARE};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

/// Random (LUT, mode) from the function zoo at a random radix 2–5.
fn random_program(rng: &mut Rng) -> (Lut, ExecMode, usize, Radix) {
    let radix = random_radix(rng);
    let tables = [full_add(radix), full_sub(radix), mac_digit(radix)];
    let table = tables[rng.index(3)].clone();
    let arity = table.arity();
    let d = StateDiagram::build(table).expect("diagram");
    let mode = if rng.chance(0.5) { ExecMode::Blocked } else { ExecMode::NonBlocked };
    let lut = match mode {
        ExecMode::Blocked => generate_blocked(&d),
        ExecMode::NonBlocked => generate_non_blocked(&d),
    };
    (lut, mode, arity, radix)
}


/// The fast path (cached kernel) equals the faithful path — contents AND
/// statistics — on both backends, and the two backends agree with each
/// other and with the row-at-a-time reference.
#[test]
fn fast_path_is_exact_on_both_backends() {
    forall(Config::cases(80), |rng: &mut Rng| {
        let (lut, mode, arity, radix) = random_program(rng);
        let rows = random_rows(rng);
        let mut data = vec![0u8; rows * arity];
        rng.fill_digits(&mut data, radix.n());
        if rng.chance(0.25) {
            // don't-care fallback must stay exact too
            data[rng.index(rows * arity)] = DONT_CARE;
        }
        let cols: Vec<usize> = (0..arity).collect();
        let positions = vec![cols.clone()];
        let cache = KernelCache::new();
        let (kernel, _) = cache.get_or_compile(&lut, mode);
        let mut snapshots = Vec::new();
        for kind in KINDS {
            let mk = || CamStorage::from_data(kind, radix, rows, arity, &data);
            let mut slow = Ap::with_storage(mk());
            slow.apply_lut(&lut, &cols, mode);
            let mut fast = Ap::with_storage(mk());
            fast.apply_lut_multi_fast_kernel(&lut, &positions, mode, &kernel);
            let mut rowwise = Ap::with_storage(mk());
            rowwise.apply_lut_multi_fast_rowwise(&lut, &positions, mode);
            let ctx = format!("{} {mode:?} {kind} rows={rows}", lut.name);
            assert_eq!(fast.storage().to_digits(), slow.storage().to_digits(), "{ctx}");
            assert_eq!(fast.stats(), slow.stats(), "{ctx}");
            assert_eq!(rowwise.storage().to_digits(), slow.storage().to_digits(), "{ctx}");
            assert_eq!(rowwise.stats(), slow.stats(), "{ctx}");
            snapshots.push((fast.storage().to_digits(), fast.stats().clone()));
        }
        assert_eq!(snapshots[0], snapshots[1], "backends diverged: {}", lut.name);
    });
}

/// Segment-attributed fast path: per-segment stats equal solo runs of the
/// segment's rows on both backends, with bounds biased to land mid-word,
/// including empty segments and planted don't-cares (isolated fallback).
#[test]
fn segmented_fast_path_is_exact_on_both_backends() {
    forall(Config::cases(50), |rng: &mut Rng| {
        let (lut, mode, arity, radix) = random_program(rng);
        let rows = random_rows(rng);
        // multi-digit layout: p positions of [a_d, b_d, carry]
        let p = 1 + rng.index(3);
        let cols_total = 2 * p + 1;
        let mut data = vec![0u8; rows * cols_total];
        rng.fill_digits(&mut data, radix.n());
        if rng.chance(0.25) {
            data[rng.index(rows * cols_total)] = DONT_CARE;
        }
        // adder-style positions (the whole zoo is arity 3)
        assert_eq!(arity, 3);
        let positions: Vec<Vec<usize>> = (0..p).map(|d| vec![d, p + d, 2 * p]).collect();
        // random cuts biased onto word boundaries and mid-word offsets
        let mut bounds: Vec<usize> = (0..rng.index(4))
            .map(|_| match rng.index(3) {
                0 if rows > 64 => 64,
                1 => rng.index(rows + 1),
                _ => rng.index(rows.min(100) + 1),
            })
            .collect();
        bounds.push(rows);
        bounds.sort_unstable();

        for kind in KINDS {
            let mk = || CamStorage::from_data(kind, radix, rows, cols_total, &data);
            let mut seg_ap = Ap::with_storage(mk());
            let segs = seg_ap.apply_lut_multi_fast_segmented(&lut, &positions, mode, &bounds);
            assert_eq!(segs.len(), bounds.len());

            // whole-array faithful reference
            let mut solo_ap = Ap::with_storage(mk());
            solo_ap.apply_lut_multi(&lut, &positions, mode);
            let ctx = format!("{} {mode:?} {kind} rows={rows} bounds={bounds:?}", lut.name);
            assert_eq!(
                seg_ap.storage().to_digits(),
                solo_ap.storage().to_digits(),
                "segmentation changed contents: {ctx}"
            );
            let total = mvap::ap::ApStats::sum_of(&segs);
            assert!(total.same_events(solo_ap.stats()), "segment sum != aggregate: {ctx}");
            assert!(seg_ap.stats().same_events(solo_ap.stats()), "{ctx}");
            assert_eq!(seg_ap.stats().compare_cycles, solo_ap.stats().compare_cycles, "{ctx}");
            assert_eq!(seg_ap.stats().write_cycles, solo_ap.stats().write_cycles, "{ctx}");

            // each segment equals a solo run of exactly its rows
            let mut start = 0usize;
            for (s, &end) in bounds.iter().enumerate() {
                if end == start {
                    assert_eq!(segs[s], mvap::ap::ApStats::default(), "{ctx}");
                    continue;
                }
                let sub = &data[start * cols_total..end * cols_total];
                let mut ap = Ap::with_storage(CamStorage::from_data(
                    kind,
                    radix,
                    end - start,
                    cols_total,
                    sub,
                ));
                ap.apply_lut_multi(&lut, &positions, mode);
                assert_eq!(&segs[s], ap.stats(), "segment {s} ({start}..{end}): {ctx}");
                start = end;
            }
        }
    });
}

/// Multi-position programs with *different* LUT arities on one `Ap`
/// (mul-style composition) exercise scratch-buffer reuse across shape
/// changes, on both backends.
#[test]
fn scratch_buffers_survive_shape_changes() {
    use mvap::ap::{load_mul_operands, mul_vectors};
    use mvap::mvl::Word;
    let mut rng = Rng::new(11);
    let radix = Radix::TERNARY;
    let p = 3;
    let rows = 70; // straddles one word boundary
    let a: Vec<Word> =
        (0..rows).map(|_| Word::from_digits(rng.number(p, 3), radix)).collect();
    let b: Vec<Word> =
        (0..rows).map(|_| Word::from_digits(rng.number(p, 3), radix)).collect();
    for mode in [ExecMode::NonBlocked, ExecMode::Blocked] {
        let (array, layout) = load_mul_operands(radix, &a, &b);
        for kind in KINDS {
            let storage = CamStorage::from_cam(kind, array.clone());
            let mut ap = Ap::with_storage(storage);
            let products = mul_vectors(&mut ap, &layout, radix, mode);
            for r in 0..rows {
                assert_eq!(
                    products[r].to_u128(),
                    a[r].to_u128() * b[r].to_u128(),
                    "row {r} {kind} {mode:?}"
                );
            }
        }
    }
}

/// A kernel compiled once drives many different arrays (the coordinator's
/// sharing pattern): results must not depend on which `Ap` ran first, and
/// the cache must serve every lookup after the first from memory.
#[test]
fn shared_kernel_is_reusable_across_arrays() {
    let radix = Radix::TERNARY;
    let d = StateDiagram::build(full_add(radix)).unwrap();
    let lut = generate_blocked(&d);
    let cache = KernelCache::new();
    let mut rng = Rng::new(23);
    for round in 0..6 {
        let (kernel, hit) = cache.get_or_compile(&lut, ExecMode::Blocked);
        assert_eq!(hit, round > 0, "round {round}");
        let rows = 1 + rng.index(200);
        let mut data = vec![0u8; rows * 3];
        rng.fill_digits(&mut data, 3);
        for kind in KINDS {
            let mut fast = Ap::with_storage(CamStorage::from_data(kind, radix, rows, 3, &data));
            fast.apply_lut_multi_fast_kernel(&lut, &[vec![0, 1, 2]], ExecMode::Blocked, &kernel);
            let mut slow = Ap::with_storage(CamStorage::from_data(kind, radix, rows, 3, &data));
            slow.apply_lut(&lut, &[0, 1, 2], ExecMode::Blocked);
            assert_eq!(fast.storage().to_digits(), slow.storage().to_digits());
            assert_eq!(fast.stats(), slow.stats());
        }
    }
    assert_eq!((cache.hits(), cache.misses()), (5, 1));
}

/// An inline-compiled kernel equals a cache-served kernel observably.
#[test]
fn inline_and_cached_kernels_agree() {
    let radix = Radix(4);
    let d = StateDiagram::build(full_sub(radix)).unwrap();
    let lut = generate_non_blocked(&d);
    let inline = LutKernel::compile(&lut, ExecMode::NonBlocked);
    let cache = KernelCache::new();
    let (cached, _) = cache.get_or_compile(&lut, ExecMode::NonBlocked);
    assert_eq!(inline.signature(), cached.signature());
    assert_eq!(inline.num_states(), cached.num_states());
    let mut rng = Rng::new(31);
    let rows = 129;
    let mut data = vec![0u8; rows * 3];
    rng.fill_digits(&mut data, radix.n());
    let positions = vec![vec![0usize, 1, 2]];
    let mut x = Ap::with_storage(CamStorage::from_data(
        StorageKind::BitSliced,
        radix,
        rows,
        3,
        &data,
    ));
    x.apply_lut_multi_fast_kernel(&lut, &positions, ExecMode::NonBlocked, &inline);
    let mut y = Ap::with_storage(CamStorage::from_data(
        StorageKind::BitSliced,
        radix,
        rows,
        3,
        &data,
    ));
    y.apply_lut_multi_fast_kernel(&lut, &positions, ExecMode::NonBlocked, &cached);
    assert_eq!(x.storage().to_digits(), y.storage().to_digits());
    assert_eq!(x.stats(), y.stats());
}
