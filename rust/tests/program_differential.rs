//! Differential suite for the program subsystem (`mvap::program`):
//!
//! * every built-in program ≡ the host digit-level reference, on both
//!   native storages, radix 2–5, word-boundary row counts, both modes;
//! * randomly generated op DAGs (the sweep that caught the fusion-
//!   liveness bug during development) ≡ the reference, including forced
//!   Copy insertion, squaring, chained and uncompacted reduces;
//! * scalar ≡ bit-sliced: outputs, per-step stats, energy, delay;
//! * `EngineService` / `ShardedService` program submission ≡ direct
//!   engine execution;
//! * per-step attribution sums to the program totals.
//!
//! Every sweep runs under `util::prop::forall`, so a failure prints a
//! `MVAP_PROP_SEED` incantation that replays the exact case.

use mvap::ap::ApStats;
use mvap::cam::StorageKind;
use mvap::coordinator::{Backend, EngineService, NativeBackend, ShardConfig, ShardedService, VectorEngine};
use mvap::mvl::{Radix, Word};
use mvap::program::{builtin, reference, BoundProgram, Program, ProgramReport, SegmentSpec};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;
use std::sync::Arc;

mod common;

use common::random_words;

fn random_rows(rng: &mut Rng) -> usize {
    // include 64-row plane-word boundaries and odd straddles
    [1, 2, 3, 7, 63, 64, 65, 100, 130, 200][rng.index(10)]
}

fn engine(kind: StorageKind) -> VectorEngine {
    VectorEngine::new(Box::new(NativeBackend::new(kind)))
}

fn run_both_storages(
    plan: &Arc<mvap::program::Plan>,
    inputs: &[(&str, Vec<Word>)],
    blocked: bool,
) -> (ProgramReport, ProgramReport) {
    let bound = BoundProgram::bind(plan, inputs.to_vec(), blocked).unwrap();
    let scalar = engine(StorageKind::Scalar).execute_program(&bound).unwrap();
    let sliced = engine(StorageKind::BitSliced).execute_program(&bound).unwrap();
    (scalar, sliced)
}

/// Assert two backends produced identical reports (modulo wall clock) and
/// that per-step attribution sums to the totals.
fn assert_reports_agree(scalar: &ProgramReport, sliced: &ProgramReport, ctx: &str) {
    assert_eq!(scalar.outputs, sliced.outputs, "{ctx}: outputs");
    assert_eq!(scalar.steps.len(), sliced.steps.len(), "{ctx}");
    for (a, b) in scalar.steps.iter().zip(&sliced.steps) {
        assert_eq!(a.stats, b.stats, "{ctx}: step '{}'", a.label);
        assert_eq!(a.energy, b.energy, "{ctx}: step '{}'", a.label);
        assert_eq!(a.delay_cycles, b.delay_cycles, "{ctx}: step '{}'", a.label);
    }
    assert_eq!(scalar.stats, sliced.stats, "{ctx}: totals");
    assert_eq!(scalar.delay_cycles, sliced.delay_cycles, "{ctx}");
    for report in [scalar, sliced] {
        let step_sum = ApStats::sum_of(
            &report.steps.iter().map(|s| s.stats.clone()).collect::<Vec<_>>(),
        );
        assert_eq!(step_sum, report.stats, "{ctx}: step stats must sum to totals");
        let delay_sum: u64 = report.steps.iter().map(|s| s.delay_cycles).sum();
        assert_eq!(delay_sum, report.delay_cycles, "{ctx}");
        let energy_sum: f64 = report.steps.iter().map(|s| s.energy.total()).sum();
        let total = report.energy.total();
        assert!(
            (energy_sum - total).abs() <= 1e-9 * total.abs() + f64::MIN_POSITIVE,
            "{ctx}: step energies {energy_sum} vs total {total}"
        );
    }
}

/// Every built-in program matches the host reference on both storages,
/// for random radices, widths, row counts, and modes.
#[test]
fn builtin_programs_match_reference() {
    forall(Config::cases(30), |rng| {
        let radix = Radix(2 + rng.digit(4)); // 2..=5
        let p = 2 + rng.index(5);
        let blocked = rng.chance(0.5);
        let rows = random_rows(rng);
        let (program, inputs): (Program, Vec<(String, Vec<Word>)>) = match rng.index(4) {
            0 => {
                let prog = builtin::dot(radix, p);
                let ins = vec![
                    ("a".to_string(), random_words(rng, rows, p, radix)),
                    ("b".to_string(), random_words(rng, rows, p, radix)),
                ];
                (prog, ins)
            }
            1 => {
                let taps = 1 + rng.index(5);
                let prog = builtin::fir(radix, p, taps);
                let mut ins = Vec::new();
                for k in 0..taps {
                    ins.push((format!("x{k}"), random_words(rng, rows, p, radix)));
                    ins.push((format!("h{k}"), random_words(rng, rows, p, radix)));
                }
                (prog, ins)
            }
            2 => {
                let degree = 1 + rng.index(4);
                let prog = builtin::poly_eval(radix, p, degree);
                let mut ins = vec![("x".to_string(), random_words(rng, rows, p, radix))];
                for k in 0..=degree {
                    ins.push((format!("c{k}"), random_words(rng, rows, p, radix)));
                }
                (prog, ins)
            }
            _ => {
                // pick a divisor of rows as the per-neuron segment size
                let divisors: Vec<usize> = (1..=rows).filter(|d| rows % d == 0).collect();
                let per = divisors[rng.index(divisors.len())];
                let prog = builtin::affine_layer(radix, p, per);
                let ins = vec![
                    ("w".to_string(), random_words(rng, rows, p, radix)),
                    ("x".to_string(), random_words(rng, rows, p, radix)),
                    ("bias".to_string(), random_words(rng, rows / per, p, radix)),
                ];
                (prog, ins)
            }
        };
        let borrowed: Vec<(&str, Vec<Word>)> =
            inputs.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
        let want = reference::evaluate(&program, &borrowed);
        let name = program.name().to_string();
        let plan = Arc::new(program.plan());
        let ctx = format!("{name} radix={} p={p} rows={rows} blocked={blocked}", radix.n());
        let (scalar, sliced) = run_both_storages(&plan, &borrowed, blocked);
        assert_eq!(scalar.outputs, want, "{ctx}");
        assert_reports_agree(&scalar, &sliced, &ctx);
    });
}

/// Random op DAGs (copies, squares, chained reduces, per-segment inputs,
/// uncompacted multi-segment outputs) match the reference on both
/// storages. This is the Rust port of the 3000-case planner sweep that
/// caught the fused-mac liveness bug in development.
#[test]
fn random_programs_match_reference() {
    forall(Config::cases(40), |rng| {
        let radix = Radix(2 + rng.digit(4));
        let p = 2 + rng.index(4);
        let blocked = rng.chance(0.5);
        let n = random_rows(rng);
        let mut prog = Program::new("fuzz", radix, p);

        // pool of (value, rows); inputs collected as (name, rows)
        let mut pool: Vec<(mvap::program::ValueId, usize)> = Vec::new();
        let mut input_rows: Vec<(String, usize)> = Vec::new();
        let n_inputs = 2 + rng.index(3);
        for i in 0..n_inputs {
            let name = format!("in{i}");
            pool.push((prog.input(&name), n));
            input_rows.push((name, n));
        }
        let n_ops = 1 + rng.index(7);
        for _ in 0..n_ops {
            if rng.chance(0.2) {
                // reduce a random value; sometimes chain computation on it
                let (v, rv) = pool[rng.index(pool.len())];
                let spec = match rng.index(3) {
                    0 => SegmentSpec::All,
                    1 => {
                        let divisors: Vec<usize> = (1..=rv).filter(|d| rv % d == 0).collect();
                        SegmentSpec::Every(divisors[rng.index(divisors.len())])
                    }
                    _ => {
                        let mut bounds = Vec::new();
                        let mut at = 0usize;
                        while at < rv {
                            at += 1 + rng.index(rv - at);
                            bounds.push(at);
                        }
                        SegmentSpec::Bounds(bounds)
                    }
                };
                let k = match &spec {
                    SegmentSpec::All => 1,
                    SegmentSpec::Every(d) => rv / d,
                    SegmentSpec::Bounds(b) => b.len(),
                };
                let s = prog.reduce(v, spec);
                pool.push((s, k));
                if rng.chance(0.5) {
                    let name = format!("like{}", input_rows.len());
                    let like = prog.input_like(&name, s);
                    pool.push((like, k));
                    input_rows.push((name, k));
                }
            } else {
                // element-wise over same-row operands (rows ⇒ same class
                // here: every per-segment class gets a distinct row count
                // only by chance — so group by the class itself)
                let (a, ra) = pool[rng.index(pool.len())];
                let same: Vec<(mvap::program::ValueId, usize)> = pool
                    .iter()
                    .copied()
                    .filter(|(v, _)| prog.row_class(*v) == prog.row_class(a))
                    .collect();
                let (b, _) = same[rng.index(same.len())];
                let op = match rng.index(3) {
                    0 => mvap::program::EwOp::Add,
                    1 => mvap::program::EwOp::Sub,
                    _ => mvap::program::EwOp::Mac,
                };
                pool.push((prog.ew(op, a, b), ra));
            }
        }
        // 1–3 random outputs
        let n_out = 1 + rng.index(3.min(pool.len()));
        let mut outs = Vec::new();
        for _ in 0..n_out {
            let (v, _) = pool[rng.index(pool.len())];
            if !outs.contains(&v) {
                prog.output(v);
                outs.push(v);
            }
        }
        if outs.is_empty() {
            prog.output(pool[0].0);
        }

        let inputs: Vec<(String, Vec<Word>)> = input_rows
            .iter()
            .map(|(name, r)| (name.clone(), random_words(rng, *r, p, radix)))
            .collect();
        let borrowed: Vec<(&str, Vec<Word>)> =
            inputs.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
        let want = reference::evaluate(&prog, &borrowed);
        let plan = Arc::new(prog.plan());
        let ctx = format!("fuzz radix={} p={p} n={n} blocked={blocked}", radix.n());
        let (scalar, sliced) = run_both_storages(&plan, &borrowed, blocked);
        assert_eq!(scalar.outputs, want, "{ctx}\nplan:\n{}", plan.render());
        assert_reports_agree(&scalar, &sliced, &ctx);
    });
}

/// Operand-preservation shapes: squaring (a ⊗ a) and a value consumed in
/// place while still live both insert copies and still match the oracle.
#[test]
fn copy_insertion_preserves_values() {
    forall(Config::cases(15), |rng| {
        let radix = Radix(2 + rng.digit(4));
        let p = 2 + rng.index(4);
        let rows = random_rows(rng);
        let mut prog = Program::new("copies", radix, p);
        let a = prog.input("a");
        let b = prog.input("b");
        let square = prog.mac(a, a); // a==b: needs a copy for distinct columns
        let y = prog.add(a, b); // destroys b...
        let z = prog.sub(b, y); // ...but b is read again here (copy) and y dies
        prog.output(square);
        prog.output(y);
        prog.output(z);
        let inputs = vec![
            ("a", random_words(rng, rows, p, radix)),
            ("b", random_words(rng, rows, p, radix)),
        ];
        let want = reference::evaluate(&prog, &inputs);
        let plan = Arc::new(prog.plan());
        let copies = plan
            .steps()
            .iter()
            .filter(|s| matches!(s.kind, mvap::program::StepKind::Copy { .. }))
            .count();
        assert!(copies >= 2, "square + live-b must both copy (got {copies})");
        let (scalar, sliced) = run_both_storages(&plan, &inputs, rng.chance(0.5));
        assert_eq!(scalar.outputs, want);
        assert_reports_agree(&scalar, &sliced, "copies");
    });
}

/// Reduce-shape corners: uncompacted multi-segment outputs extract from
/// the segment head rows; a reduce chained on a compacted reduce output
/// folds only its shrunken live range.
#[test]
fn reduce_corners_match_reference() {
    forall(Config::cases(15), |rng| {
        let radix = Radix(2 + rng.digit(4));
        let p = 2 + rng.index(4);
        let rows = 2 + rng.index(190);
        let mut prog = Program::new("corners", radix, p);
        let a = prog.input("a");
        // random multi-segment cut, output uncompacted
        let mut bounds = Vec::new();
        let mut at = 0usize;
        while at < rows {
            at += 1 + rng.index(rows - at);
            bounds.push(at);
        }
        let s1 = prog.reduce(a, SegmentSpec::Bounds(bounds));
        // chain: fold the per-segment sums down to one value
        let s2 = prog.reduce(s1, SegmentSpec::All);
        prog.output(s1); // s1 is consumed AND an output ⇒ copied + compacted
        prog.output(s2);
        let inputs = vec![("a", random_words(rng, rows, p, radix))];
        let want = reference::evaluate(&prog, &inputs);
        let plan = Arc::new(prog.plan());
        let (scalar, sliced) = run_both_storages(&plan, &inputs, rng.chance(0.5));
        assert_eq!(scalar.outputs, want, "rows={rows}\n{}", plan.render());
        assert_reports_agree(&scalar, &sliced, "corners");
    });
}

/// dot over single-digit operands is integer-exact (the NN workload).
#[test]
fn dot_is_integer_exact_for_single_digit_operands() {
    forall(Config::cases(15), |rng| {
        let radix = Radix(2 + rng.digit(4));
        let p = 6;
        let rows = 1 + rng.index(300);
        let single = |rng: &mut Rng| -> Vec<Word> {
            (0..rows)
                .map(|_| Word::from_u128(rng.digit(radix.n()) as u128, p, radix))
                .collect()
        };
        let a = single(rng);
        let b = single(rng);
        let want: u128 = a.iter().zip(&b).map(|(x, y)| x.to_u128() * y.to_u128()).sum();
        if want >= (radix.n() as u128).pow(p as u32) {
            return; // accumulator would wrap; covered by the mod oracle
        }
        let plan = Arc::new(builtin::dot(radix, p).plan());
        let inputs = vec![("a", a), ("b", b)];
        let (scalar, _) = run_both_storages(&plan, &inputs, true);
        assert_eq!(scalar.outputs[0][0].to_u128(), want, "rows={rows}");
    });
}

/// Program submission through `EngineService` and `ShardedService`
/// produces byte-identical reports to direct engine execution (modulo
/// wall clock), and the per-worker metrics aggregate.
#[test]
fn services_match_direct_engine() {
    forall(Config::cases(6), |rng| {
        let radix = Radix::TERNARY;
        let p = 2 + rng.index(5);
        let rows = random_rows(rng);
        let plan = Arc::new(builtin::fir(radix, p, 1 + rng.index(4)).plan());
        let names = plan.program().input_names();
        let inputs: Vec<(String, Vec<Word>)> = names
            .iter()
            .map(|n| (n.to_string(), random_words(rng, rows, p, radix)))
            .collect();
        let borrowed: Vec<(&str, Vec<Word>)> =
            inputs.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
        let blocked = rng.chance(0.5);
        let bound = BoundProgram::bind(&plan, borrowed, blocked).unwrap();

        let mut direct = engine(StorageKind::Scalar);
        let want = direct.execute_program(&bound).unwrap();

        let svc = EngineService::start(2, 4, || {
            Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
        })
        .unwrap();
        let got = svc.run_program(bound.clone()).unwrap();
        let m = svc.shutdown();
        assert_eq!(got.outputs, want.outputs);
        assert_eq!(got.stats, want.stats);
        assert_eq!(got.delay_cycles, want.delay_cycles);
        assert_eq!(m.programs, 1);
        assert_eq!(m.program_steps, want.steps.len() as u64);

        let cfg = ShardConfig { shards: 2, ..ShardConfig::default() };
        let svc = ShardedService::start(cfg, || {
            Ok(Box::new(NativeBackend::bit_sliced()) as Box<dyn Backend>)
        })
        .unwrap();
        let got = svc.run_program(bound).unwrap();
        let (agg, _) = svc.shutdown();
        assert_eq!(got.outputs, want.outputs);
        assert_eq!(got.stats, want.stats, "sharded bit-sliced ≡ direct scalar");
        assert_eq!(agg.programs, 1);
    });
}
