//! Property tests for the row-movement primitives (`copy_rows` /
//! `fill_rows`) that the in-engine tree reduction and the program
//! compiler's segment compaction lean on. The bit-sliced backend moves
//! whole 64-row plane words with shifts, so the risky edges are exactly
//! the word-shift ones: zero-length ranges, full-word-aligned offsets vs
//! mid-word offsets, ranges straddling word boundaries, and overlapping
//! same-column copies (memmove semantics).
//!
//! Every case is checked three ways: scalar backend ≡ bit-sliced backend
//! ≡ a naive snapshot reference (copying from a pre-copy snapshot is
//! memmove semantics by construction).
//!
//! Replay a failing case with `MVAP_PROP_SEED=0x… cargo test -q --test
//! row_movement` (the seed is printed in the failure message).

use mvap::mvl::{Radix, DONT_CARE};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

mod common;

use common::{random_data, random_radix, storage_pair};

/// Naive reference: copy through a full snapshot of the digit grid.
fn reference_copy(
    digits: &mut Vec<u8>,
    cols: usize,
    src_col: usize,
    src_row: usize,
    dst_col: usize,
    dst_row: usize,
    count: usize,
) {
    let snapshot = digits.clone();
    for i in 0..count {
        digits[(dst_row + i) * cols + dst_col] = snapshot[(src_row + i) * cols + src_col];
    }
}

/// Offsets that exercise word-aligned, mid-word, and boundary-straddling
/// shifts in a 3-word (192-row) column.
const EDGES: [usize; 8] = [0, 1, 31, 63, 64, 65, 127, 128];

/// Exhaustive word-shift edges: every (src_row, dst_row, count) over the
/// edge offsets, including zero-length and overlapping same-column
/// ranges in both directions, on both backends.
#[test]
fn copy_rows_word_shift_edges() {
    let rows = 192;
    let mut rng = Rng::new(0x10f5);
    for radix in [Radix::BINARY, Radix::TERNARY, Radix(5)] {
        let data = random_data(&mut rng, rows, 2, radix, 0.15);
        for &src_row in &EDGES {
            for &dst_row in &EDGES {
                for count in [0, 1, 63, 64, 65, rows - 128] {
                    if src_row + count > rows || dst_row + count > rows {
                        continue;
                    }
                    for (src_col, dst_col) in [(0, 1), (0, 0)] {
                        let (mut scalar, mut sliced) = storage_pair(radix, rows, 2, &data);
                        let mut expect = data.clone();
                        reference_copy(
                            &mut expect, 2, src_col, src_row, dst_col, dst_row, count,
                        );
                        scalar.copy_rows(src_col, src_row, dst_col, dst_row, count);
                        sliced.copy_rows(src_col, src_row, dst_col, dst_row, count);
                        let ctx = format!(
                            "radix {} copy c{src_col}r{src_row} -> c{dst_col}r{dst_row} ×{count}",
                            radix.n()
                        );
                        assert_eq!(scalar.to_digits(), expect, "scalar vs reference: {ctx}");
                        assert_eq!(sliced.to_digits(), expect, "bit-sliced vs reference: {ctx}");
                    }
                }
            }
        }
    }
}

/// Randomized copies over random shapes: the three-way agreement holds
/// for arbitrary (not just edge-aligned) offsets, with don't-care rows
/// travelling along (the present plane moves with the digit planes).
#[test]
fn copy_rows_scalar_matches_bitsliced_randomized() {
    forall(Config::cases(120), |rng| {
        let radix = random_radix(rng);
        let rows = 1 + rng.index(300);
        let cols = 1 + rng.index(3);
        let data = random_data(rng, rows, cols, radix, 0.2);
        let (mut scalar, mut sliced) = storage_pair(radix, rows, cols, &data);
        let src_col = rng.index(cols);
        let dst_col = rng.index(cols);
        let count = rng.index(rows + 1);
        let src_row = rng.index(rows - count + 1);
        let dst_row = rng.index(rows - count + 1);
        let mut expect = data.clone();
        reference_copy(&mut expect, cols, src_col, src_row, dst_col, dst_row, count);
        scalar.copy_rows(src_col, src_row, dst_col, dst_row, count);
        sliced.copy_rows(src_col, src_row, dst_col, dst_row, count);
        assert_eq!(scalar.to_digits(), expect, "scalar vs reference");
        assert_eq!(sliced.to_digits(), expect, "bit-sliced vs reference");
    });
}

/// A copy fully onto itself (same column, same offset) is the identity,
/// whatever the count — the bit-sliced fast path must not clobber.
#[test]
fn copy_rows_self_copy_is_identity() {
    forall(Config::cases(40), |rng| {
        let radix = random_radix(rng);
        let rows = 1 + rng.index(200);
        let data = random_data(rng, rows, 1, radix, 0.2);
        let (mut scalar, mut sliced) = storage_pair(radix, rows, 1, &data);
        let count = rng.index(rows + 1);
        let row = rng.index(rows - count + 1);
        scalar.copy_rows(0, row, 0, row, count);
        sliced.copy_rows(0, row, 0, row, count);
        assert_eq!(scalar.to_digits(), data, "scalar self-copy must be a no-op");
        assert_eq!(sliced.to_digits(), data, "bit-sliced self-copy must be a no-op");
    });
}

/// `fill_rows` on both backends against the obvious reference, over the
/// word-shift edges and random ranges, including zero-length fills and
/// don't-care fills (which clear the present plane).
#[test]
fn fill_rows_matches_reference() {
    let rows = 192;
    let mut rng = Rng::new(0xf111);
    for radix in [Radix::BINARY, Radix::TERNARY, Radix(5)] {
        let data = random_data(&mut rng, rows, 2, radix, 0.15);
        for &start in &EDGES {
            for count in [0, 1, 63, 64, 65, rows - 128] {
                if start + count > rows {
                    continue;
                }
                for digit in [0, radix.n() - 1, DONT_CARE] {
                    let (mut scalar, mut sliced) = storage_pair(radix, rows, 2, &data);
                    let mut expect = data.clone();
                    for r in start..start + count {
                        expect[r * 2 + 1] = digit;
                    }
                    scalar.fill_rows(1, start, count, digit);
                    sliced.fill_rows(1, start, count, digit);
                    let ctx = format!("radix {} fill r{start} ×{count} = {digit}", radix.n());
                    assert_eq!(scalar.to_digits(), expect, "scalar: {ctx}");
                    assert_eq!(sliced.to_digits(), expect, "bit-sliced: {ctx}");
                }
            }
        }
    }
    forall(Config::cases(60), |rng| {
        let radix = random_radix(rng);
        let rows = 1 + rng.index(300);
        let data = random_data(rng, rows, 1, radix, 0.2);
        let (mut scalar, mut sliced) = storage_pair(radix, rows, 1, &data);
        let count = rng.index(rows + 1);
        let start = rng.index(rows - count + 1);
        let digit = if rng.chance(0.2) { DONT_CARE } else { rng.digit(radix.n()) };
        let mut expect = data.clone();
        for e in expect.iter_mut().skip(start).take(count) {
            *e = digit;
        }
        scalar.fill_rows(0, start, count, digit);
        sliced.fill_rows(0, start, count, digit);
        assert_eq!(scalar.to_digits(), expect, "scalar");
        assert_eq!(sliced.to_digits(), expect, "bit-sliced");
    });
}

/// Copies round-trip through both storages identically even when the
/// destination column then participates in a compare — the moved
/// don't-care rows must match any key on both backends.
#[test]
fn moved_dont_cares_still_match_everything() {
    forall(Config::cases(30), |rng| {
        let radix = random_radix(rng);
        let rows = 1 + rng.index(150);
        let data = random_data(rng, rows, 2, radix, 0.5);
        let (mut scalar, mut sliced) = storage_pair(radix, rows, 2, &data);
        let count = rng.index(rows + 1);
        let src_row = rng.index(rows - count + 1);
        let dst_row = rng.index(rows - count + 1);
        for s in [&mut scalar, &mut sliced] {
            s.copy_rows(0, src_row, 1, dst_row, count);
        }
        let key = rng.digit(radix.n());
        let a = scalar.compare(&[1], &[key]);
        let b = sliced.compare(&[1], &[key]);
        assert_eq!(a.tags, b.tags, "compare tags diverged after copy");
        assert_eq!(a.mismatch_hist, b.mismatch_hist);
    });
}

/// CamStorage constructors used by `storage_pair` agree from the start —
/// a guard for the helper itself on degenerate shapes.
#[test]
fn storage_pair_agrees_on_degenerate_shapes() {
    for (rows, cols) in [(1, 1), (64, 1), (65, 2), (128, 3)] {
        let mut rng = Rng::new((rows * 31 + cols) as u64);
        let radix = Radix::TERNARY;
        let data = random_data(&mut rng, rows, cols, radix, 0.3);
        let (scalar, sliced) = storage_pair(radix, rows, cols, &data);
        assert_eq!(scalar.to_digits(), data);
        assert_eq!(sliced.to_digits(), data);
        assert_eq!(scalar.rows(), rows);
        assert_eq!(sliced.rows(), rows);
    }
}
