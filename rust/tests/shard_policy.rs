//! Deterministic state-machine property test of the shard batching
//! policy ([`mvap::coordinator::BatchPolicy`]) — the flush/steal/shutdown
//! decision core extracted from the shard worker loop so its policy logic
//! is checkable single-threaded: a random event sequence (job arrivals
//! across signatures, clock advances, timeout ticks, close) drives both
//! the policy and an independent reference model on a **synthetic
//! logical clock** (the policy's `Nanos` timeline — no `Instant`s);
//! after every event the two must agree, and the global invariants must
//! hold:
//!
//! * every admitted job is flushed exactly once, in admission order;
//! * every flushed batch is signature-coherent;
//! * a batch never exceeds `max_batch_jobs`, and only reaches
//!   `max_batch_rows` on its final (flushing) job;
//! * a partial batch never outlives its deadline across a timeout tick;
//! * stealing is permitted exactly while nothing is pending;
//! * close flushes the remainder.
//!
//! This random sweep complements the *exhaustive* bounded-interleaving
//! check in `rust/tests/shard_modelcheck.rs`: the sweep covers wide
//! numeric ranges (row counts, thresholds, clock skews), the checker
//! covers every scheduling order of small scenarios. Failures replay
//! exactly via the printed seed (`MVAP_PROP_SEED`).

mod common;

use common::sig_with_digits as sig;
use mvap::coordinator::shard_machine::duration_nanos;
use mvap::coordinator::{BatchPolicy, JobSignature, ShardConfig};
use mvap::util::prop::{forall, Config};
use std::time::Duration;

/// Reference model: the batching rules, restated independently.
struct Model {
    max_jobs: usize,
    max_rows: usize,
    flush_after: u64,
    /// (job id, rows) of the pending batch, admission order.
    pending: Vec<(u64, usize)>,
    pending_sig: Option<JobSignature>,
    deadline: Option<u64>,
    /// Flushed batches, each a list of job ids.
    flushed: Vec<Vec<u64>>,
}

impl Model {
    fn flush(&mut self) {
        if !self.pending.is_empty() {
            self.flushed.push(self.pending.iter().map(|&(id, _)| id).collect());
            self.pending.clear();
            self.pending_sig = None;
            self.deadline = None;
        }
    }
}

#[test]
fn batch_policy_matches_reference_model() {
    forall(Config::cases(300), |rng| {
        let cfg = ShardConfig {
            max_batch_jobs: 1 + rng.index(5),
            max_batch_rows: 1 + rng.index(200),
            flush_after: Duration::from_millis(1 + rng.index(20) as u64),
            ..ShardConfig::default()
        };
        let flush_after = duration_nanos(cfg.flush_after);
        let mut policy = BatchPolicy::new(&cfg);
        let mut model = Model {
            max_jobs: cfg.max_batch_jobs,
            max_rows: cfg.max_batch_rows,
            flush_after,
            pending: Vec::new(),
            pending_sig: None,
            deadline: None,
            flushed: Vec::new(),
        };
        // synthetic logical clock, advanced by random steps
        let mut now: u64 = 0;
        let mut next_id = 0u64;
        let mut policy_flushes = 0usize;

        let steps = 1 + rng.index(60);
        for _ in 0..steps {
            // advance the clock by 0..3·flush_after
            now += (flush_after as f64 * 3.0 * rng.f64()) as u64;
            match rng.index(4) {
                // --- a job arrives -----------------------------------
                0 | 1 => {
                    let s = sig(3 + rng.index(3)); // 3 signatures in play
                    let rows = 1 + rng.index(80);
                    let id = next_id;
                    next_id += 1;

                    // model: signature switch flushes first
                    let switch =
                        model.pending_sig.map_or(false, |ps| ps != s);
                    assert_eq!(
                        policy.must_flush_before(s),
                        switch,
                        "flush-before divergence"
                    );
                    if switch {
                        model.flush();
                        policy_flushes += 1;
                        policy.flushed();
                    }
                    if model.pending.is_empty() {
                        model.deadline = Some(now + model.flush_after);
                        model.pending_sig = Some(s);
                    }
                    model.pending.push((id, rows));
                    let model_rows: usize =
                        model.pending.iter().map(|&(_, r)| r).sum();
                    let model_flush_now = model.pending.len() >= model.max_jobs
                        || model_rows >= model.max_rows
                        || model.deadline.map_or(false, |d| now >= d);

                    let policy_flush_now = policy.admit(s, rows, now);
                    assert_eq!(policy_flush_now, model_flush_now, "admit divergence");
                    // a batch never exceeds the job cap
                    assert!(model.pending.len() <= model.max_jobs);
                    if model.pending.len() == model.max_jobs {
                        assert!(model_flush_now, "full batches must flush");
                    }
                    if model_flush_now {
                        model.flush();
                        policy_flushes += 1;
                        policy.flushed();
                    }
                }
                // --- a timeout tick ----------------------------------
                2 => {
                    let model_should = !model.pending.is_empty()
                        && model.deadline.map_or(false, |d| now >= d);
                    assert_eq!(policy.should_flush(now), model_should, "tick divergence");
                    if model_should {
                        model.flush();
                        policy_flushes += 1;
                        policy.flushed();
                    }
                    // after the tick no expired partial batch survives
                    assert!(!policy.should_flush(now));
                }
                // --- an idle wait computation ------------------------
                _ => {
                    let idle = Duration::from_millis(500);
                    let want = match model.deadline {
                        Some(d) if !model.pending.is_empty() => {
                            Duration::from_nanos(d.saturating_sub(now))
                        }
                        _ => idle,
                    };
                    assert_eq!(policy.wait(now, idle), want, "wait divergence");
                }
            }
            // --- continuous invariants ------------------------------
            assert_eq!(policy.pending_jobs(), model.pending.len());
            assert_eq!(
                policy.pending_rows(),
                model.pending.iter().map(|&(_, r)| r).sum::<usize>()
            );
            assert_eq!(policy.signature(), model.pending_sig);
            assert_eq!(policy.may_steal(), model.pending.is_empty(), "steal gating");
        }
        // --- close: the remainder flushes ---------------------------
        let had_pending = !model.pending.is_empty();
        model.flush();
        if had_pending {
            policy_flushes += 1;
            policy.flushed();
        }
        assert_eq!(policy.pending_jobs(), 0);
        assert_eq!(policy_flushes, model.flushed.len());

        // every admitted job flushed exactly once, in admission order
        let flushed_ids: Vec<u64> =
            model.flushed.iter().flatten().copied().collect();
        assert_eq!(flushed_ids, (0..next_id).collect::<Vec<u64>>());
        // every flushed batch respects the caps (rows may only be
        // reached by its final job — earlier jobs would have flushed)
        for batch in &model.flushed {
            assert!(!batch.is_empty());
            assert!(batch.len() <= cfg.max_batch_jobs);
        }
    });
}

/// `rebase` is sound against the reference model: rebasing the policy
/// and restarting the model clock at the batch anchor leaves every
/// observable decision unchanged (the time-shift quotient the model
/// checker relies on).
#[test]
fn rebase_preserves_decisions() {
    forall(Config::cases(200), |rng| {
        let cfg = ShardConfig {
            max_batch_jobs: 2 + rng.index(4),
            max_batch_rows: 50 + rng.index(200),
            flush_after: Duration::from_millis(1 + rng.index(10) as u64),
            ..ShardConfig::default()
        };
        let flush_after = duration_nanos(cfg.flush_after);
        let mut a = BatchPolicy::new(&cfg);
        let mut b = BatchPolicy::new(&cfg);
        let s = sig(3);
        // a starts its batch at a random offset, b at time zero
        let start = (flush_after as f64 * 2.0 * rng.f64()) as u64;
        assert_eq!(a.admit(s, 1, start), b.admit(s, 1, 0));
        a.rebase();
        assert_eq!(a, b, "rebase quotients out the batch start time");
        // identical event streams keep the rebased policies equal
        for _ in 0..5 {
            let dt = (flush_after as f64 * 1.5 * rng.f64()) as u64;
            assert_eq!(a.should_flush(dt), b.should_flush(dt));
            assert_eq!(a.wait(dt, Duration::from_secs(1)), b.wait(dt, Duration::from_secs(1)));
            let flushes = a.admit(s, 1, dt);
            assert_eq!(flushes, b.admit(s, 1, dt));
            assert_eq!(a, b);
            if flushes {
                a.flushed();
                b.flushed();
            }
        }
        // rebasing an empty policy is the identity
        a.flushed();
        b.flushed();
        a.rebase();
        assert_eq!(a, b);
    });
}
