//! Differential property tests for the in-engine content-addressable ops
//! (`OpKind::{Search, Min, Max, TopK}`): the bit-sliced plane-native path
//! must be observably identical to the scalar path — hit sets, reported
//! values, per-job statistics, energy, and modeled delay — and both must
//! match the pure host oracles, for radices 2–5, row counts straddling
//! 64-row plane-word boundaries, segment cuts landing mid-word, stored
//! don't-care digits, and data-parallel thread counts 1 and 4 (search is
//! a compare-only schedule, so the knob must be a pure no-op). Coalesced
//! batches of same-signature search jobs must equal solo execution
//! exactly — the stats-exactness the coordinator's batching relies on —
//! and the threaded service front door must agree with a direct engine.
//!
//! Replay a failing case with `MVAP_PROP_SEED=0x… cargo test -q --test
//! search_differential` (the seed is printed in the failure message);
//! ci.sh runs a fixed-seed pass of exactly this suite as its
//! reproduction stage.

use mvap::ap::{
    host_exact, host_extreme, host_extreme_passes, host_nearest, host_topk, host_topk_passes,
    ApStats, SearchQuery,
};
use mvap::cam::Parallelism;
use mvap::coordinator::{
    BackendKind, EngineService, Job, JobResult, NativeBackend, VectorEngine,
};
use mvap::energy::CompareEnergy;
use mvap::mvl::{Radix, Word, DONT_CARE};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

mod common;

use common::{boundary_rows, random_digit, random_radix, KINDS};

/// Random strictly-increasing segment bounds over `rows` rows; cuts are
/// uniform, so they routinely land mid-word.
fn random_segments(rng: &mut Rng, rows: usize) -> Vec<usize> {
    let mut bounds = Vec::new();
    let mut at = 0usize;
    while at < rows {
        at += 1 + rng.index(rows - at);
        bounds.push(at);
    }
    bounds
}

/// `rows` random `p`-digit words with the given don't-care density.
fn random_wild_words(rng: &mut Rng, rows: usize, p: usize, radix: Radix, dc: f64) -> Vec<Word> {
    (0..rows)
        .map(|_| {
            Word::from_digits_wild(
                (0..p).map(|_| random_digit(rng, radix.n(), dc)).collect(),
                radix,
            )
        })
        .collect()
}

/// A random key: a stored row half the time (guaranteed exact hits),
/// otherwise fresh digits with a light wildcard density.
fn random_key(rng: &mut Rng, values: &[Word], p: usize, radix: Radix) -> Word {
    if rng.chance(0.5) {
        values[rng.index(values.len())].clone()
    } else {
        Word::from_digits_wild(
            (0..p).map(|_| random_digit(rng, radix.n(), 0.05)).collect(),
            radix,
        )
    }
}

/// A random search-class job of any of the five query shapes over the
/// given operands and segment bounds.
fn random_search_job(
    rng: &mut Rng,
    id: u64,
    radix: Radix,
    values: Vec<Word>,
    segments: Vec<usize>,
) -> Job {
    let p = values[0].width();
    match rng.index(5) {
        0 => {
            let key = random_key(rng, &values, p, radix);
            Job::search(id, radix, values, key, false, segments)
        }
        1 => {
            let key = random_key(rng, &values, p, radix);
            Job::search(id, radix, values, key, true, segments)
        }
        2 => Job::min(id, radix, values, segments),
        3 => Job::max(id, radix, values, segments),
        _ => {
            let k = rng.index(values.len() + 3);
            let largest = rng.chance(0.5);
            Job::topk(id, radix, values, k, largest, segments)
        }
    }
}

/// The full oracle check of one search-job result: per-segment hit rows,
/// reported stored values, distances, and pass counts against the host
/// references; pass/stat/delay consistency; the read-only energy model
/// (zero writes, compare energy = the histogram priced by the
/// radix-appropriate §VI-A table).
fn check_against_host(job: &Job, res: &JobResult) {
    assert!(res.values.is_empty(), "search jobs return hits, not per-row values");
    assert_eq!(res.hits.len(), job.segments().len(), "one hit set per segment");
    let query = job.query().expect("search job carries a query");
    let mut start = 0usize;
    for (s, (&end, hits)) in job.segments().iter().zip(&res.hits).enumerate() {
        let seg = &job.a[start..end];
        match query {
            SearchQuery::Exact { key } => {
                assert_eq!(hits.rows, host_exact(seg, key), "segment {s}: exact rows");
                assert_eq!(hits.distance, 0, "segment {s}");
                assert_eq!(hits.passes, 1, "segment {s}: exact match is one cycle");
            }
            SearchQuery::Nearest { key } => {
                let (rows, dist) = host_nearest(seg, key);
                assert_eq!(hits.rows, rows, "segment {s}: nearest rows");
                assert_eq!(hits.distance, dist, "segment {s}: distance");
                assert_eq!(hits.passes, key.width() as u64, "segment {s}: one cycle per digit");
            }
            SearchQuery::Extreme { largest } => {
                assert_eq!(hits.rows, host_extreme(seg, *largest), "segment {s}: extreme rows");
                assert_eq!(hits.passes, host_extreme_passes(seg, *largest), "segment {s}");
            }
            SearchQuery::TopK { k, largest } => {
                assert_eq!(hits.rows, host_topk(seg, *k, *largest), "segment {s}: topk ranking");
                assert_eq!(hits.passes, host_topk_passes(seg, *k, *largest), "segment {s}");
            }
        }
        for (&r, v) in hits.rows.iter().zip(&hits.values) {
            assert_eq!(v, &seg[r], "segment {s}: reported value is the stored word");
        }
        start = end;
    }
    // pass/stat/delay consistency: the pass total IS the cycle count
    let pass_sum: u64 = res.hits.iter().map(|h| h.passes).sum();
    assert_eq!(res.stats.compare_cycles, pass_sum, "stats sum the per-segment passes");
    assert_eq!(res.delay_cycles, res.stats.compare_cycles, "delay = compare passes");
    // search ops are read-only: compare energy only, priced per class
    assert_eq!(res.stats.write_cycles, 0);
    assert_eq!(res.stats.write_ops(), 0);
    assert_eq!(res.energy.write, 0.0);
    assert_eq!(res.energy.write_ops, 0);
    let table = if job.radix.n() == 2 {
        CompareEnergy::default_binary()
    } else {
        CompareEnergy::default_ternary()
    };
    let want: f64 = res
        .stats
        .mismatch_hist
        .iter()
        .enumerate()
        .map(|(k, &c)| c as f64 * table.class(k))
        .sum();
    assert!(
        (res.energy.compare - want).abs() < 1e-21,
        "compare energy {} != histogram pricing {want}",
        res.energy.compare
    );
}

/// The core differential: every query shape on both storage backends at
/// data-parallel thread counts 1 and 4 — identical hits, stats, energy,
/// and delay across all four combinations, all matching the host
/// oracles, over boundary-straddling row counts and mid-word segment
/// cuts with stored don't-care digits.
#[test]
fn search_jobs_scalar_vs_bitsliced_differential() {
    forall(Config::cases(50), |rng| {
        let radix = random_radix(rng);
        let p = 1 + rng.index(6);
        let rows = boundary_rows(rng);
        let values = random_wild_words(rng, rows, p, radix, 0.05);
        let segments = random_segments(rng, rows);
        let job = random_search_job(rng, 1, radix, values, segments);
        let mut runs = Vec::new();
        for kind in KINDS {
            for threads in [1usize, 4] {
                let backend =
                    NativeBackend::new(kind).with_parallelism(Parallelism::new(threads));
                let mut eng = VectorEngine::new(Box::new(backend));
                let res = eng.execute(&job).unwrap();
                check_against_host(&job, &res);
                runs.push((kind, threads, res));
            }
        }
        let (k0, t0, first) = &runs[0];
        for (kind, threads, res) in &runs[1..] {
            let tag = format!("{kind:?}x{threads} vs {k0:?}x{t0}");
            assert_eq!(res.hits, first.hits, "{tag}: hits diverged");
            assert_eq!(res.stats, first.stats, "{tag}: stats diverged");
            assert_eq!(res.energy, first.energy, "{tag}: energy diverged");
            assert_eq!(res.delay_cycles, first.delay_cycles, "{tag}: delay diverged");
        }
    });
}

/// Coalesced batches of same-signature search jobs equal solo execution
/// exactly — hits, stats, energy, delay — on both backends. Signatures
/// key on (op, radix, digits) only: row counts, segment structures, and
/// keys may all differ across a batch, because read-only segments never
/// interact on the shared array.
#[test]
fn coalesced_search_batches_match_solo_runs() {
    forall(Config::cases(12), |rng| {
        let radix = random_radix(rng);
        let p = 1 + rng.index(5);
        let shape = rng.index(5); // one query shape per batch (same OpKind)
        let njobs = 2 + rng.index(3);
        let jobs: Vec<Job> = (0..njobs)
            .map(|id| {
                let rows = 1 + rng.index(120);
                let values = random_wild_words(rng, rows, p, radix, 0.05);
                let segments = random_segments(rng, rows);
                match shape {
                    0 | 1 => {
                        let key = random_key(rng, &values, p, radix);
                        Job::search(id as u64, radix, values, key, shape == 1, segments)
                    }
                    2 => Job::min(id as u64, radix, values, segments),
                    3 => Job::max(id as u64, radix, values, segments),
                    _ => {
                        let k = rng.index(values.len() + 3);
                        Job::topk(id as u64, radix, values, k, rng.chance(0.5), segments)
                    }
                }
            })
            .collect();
        let sig = jobs[0].signature();
        assert!(jobs.iter().all(|j| j.signature() == sig), "search batches share a signature");
        for kind in KINDS {
            let mut solo = VectorEngine::new(Box::new(NativeBackend::new(kind)));
            let want: Vec<_> = jobs.iter().map(|j| solo.execute(j).unwrap()).collect();
            let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
            let got = eng.execute_coalesced(&jobs).unwrap();
            assert_eq!(got.len(), want.len());
            for ((g, w), job) in got.iter().zip(&want).zip(&jobs) {
                assert_eq!(g.hits, w.hits, "job {} ({kind:?}): coalesced hits", g.id);
                assert_eq!(g.stats, w.stats, "job {} ({kind:?}): coalesced stats", g.id);
                assert_eq!(g.energy, w.energy, "job {} ({kind:?})", g.id);
                assert_eq!(g.delay_cycles, w.delay_cycles, "job {} ({kind:?})", g.id);
                check_against_host(job, g);
            }
        }
    });
}

/// The edge shapes, end to end through the engine on both backends:
/// misses still cost their compare cycle, all-equal arrays tie on every
/// row, duplicate extremes break ties ascending, `k = 0` is free,
/// `k > rows` returns the full ordering, a single row eliminates for
/// free, and stored don't-care digits match any key and rank as the
/// scan-best value.
#[test]
fn search_edge_cases_through_engine() {
    let radix = Radix::TERNARY;
    for kind in KINDS {
        let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
        // single row: a lone candidate needs no elimination passes
        let one = vec![Word::from_u128(5, 3, radix)];
        let res = eng.execute(&Job::min(1, radix, one, vec![])).unwrap();
        assert_eq!(res.hits[0].rows, vec![0], "{kind:?}");
        assert_eq!(res.delay_cycles, 0, "{kind:?}: single-row min is free");
        assert_eq!(res.energy.total(), 0.0, "{kind:?}");
        // empty match set: a miss still costs the one compare cycle
        let vals: Vec<Word> =
            [3u128, 8, 12].iter().map(|&v| Word::from_u128(v, 3, radix)).collect();
        let key = Word::from_u128(25, 3, radix);
        let res = eng.execute(&Job::search(2, radix, vals, key, false, vec![])).unwrap();
        assert!(res.hits[0].rows.is_empty(), "{kind:?}");
        assert_eq!(res.delay_cycles, 1, "{kind:?}: a miss is one compare cycle");
        assert!(res.energy.compare > 0.0, "{kind:?}");
        // all rows equal: every row ties, ascending
        let dup = vec![Word::from_u128(7, 3, radix); 4];
        let res = eng.execute(&Job::max(3, radix, dup, vec![])).unwrap();
        assert_eq!(res.hits[0].rows, vec![0, 1, 2, 3], "{kind:?}: ties report every row");
        // duplicate extremes under TopK: ties break by ascending row
        let vals: Vec<Word> =
            [5u128, 7, 5, 1, 7].iter().map(|&v| Word::from_u128(v, 3, radix)).collect();
        let res = eng.execute(&Job::topk(4, radix, vals.clone(), 3, true, vec![])).unwrap();
        assert_eq!(res.hits[0].rows, vec![1, 4, 0], "{kind:?}");
        // k = 0 is free; k > rows returns the full ordering
        let res = eng.execute(&Job::topk(5, radix, vals.clone(), 0, true, vec![])).unwrap();
        assert!(res.hits[0].rows.is_empty(), "{kind:?}");
        assert_eq!(res.stats, ApStats::default(), "{kind:?}: k = 0 costs nothing");
        let res = eng.execute(&Job::topk(6, radix, vals.clone(), 99, false, vec![])).unwrap();
        assert_eq!(res.hits[0].rows, vec![3, 0, 2, 1, 4], "{kind:?}: full ascending ordering");
        assert_eq!(res.hits[0].rows.len(), vals.len(), "{kind:?}");
        // stored don't-care digits: [*, 1, 0] matches keys 3..=5 and
        // ranks as value 3 (wildcard ⇒ scan-best 0) under Min
        let wild = vec![
            Word::from_digits_wild(vec![DONT_CARE, 1, 0], radix),
            Word::from_u128(4, 3, radix),
        ];
        let key = Word::from_u128(4, 3, radix);
        let res =
            eng.execute(&Job::search(7, radix, wild.clone(), key, false, vec![])).unwrap();
        assert_eq!(res.hits[0].rows, vec![0, 1], "{kind:?}: wildcard matches the key too");
        let res = eng.execute(&Job::min(8, radix, wild, vec![])).unwrap();
        assert_eq!(res.hits[0].rows, vec![0], "{kind:?}: wildcard ranks as scan-best");
    }
}

/// The threaded service front door returns bit-identical results to a
/// direct engine for every query shape, on both native backend kinds —
/// the submission path adds queueing, never behavior.
#[test]
fn search_jobs_match_through_the_service() {
    let radix = Radix::TERNARY;
    let mut rng = Rng::new(31);
    let p = 4;
    let rows = 70; // straddles a 64-row plane-word boundary
    let values = random_wild_words(&mut rng, rows, p, radix, 0.05);
    let key = values[rng.index(rows)].clone();
    let jobs = vec![
        Job::search(1, radix, values.clone(), key.clone(), false, vec![35, 70]),
        Job::search(2, radix, values.clone(), key, true, vec![]),
        Job::min(3, radix, values.clone(), vec![20, 40, 70]),
        Job::topk(4, radix, values.clone(), 5, true, vec![]),
    ];
    for (backend_kind, storage) in [
        (BackendKind::Native, KINDS[0]),
        (BackendKind::NativeBitSliced, KINDS[1]),
    ] {
        let svc = EngineService::start_kind(2, 4, backend_kind, std::path::PathBuf::from("."))
            .unwrap();
        let mut eng = VectorEngine::new(Box::new(NativeBackend::new(storage)));
        for job in &jobs {
            let got = svc.run(job.clone()).unwrap();
            let want = eng.execute(job).unwrap();
            assert_eq!(got.hits, want.hits, "job {} ({backend_kind:?})", job.id);
            assert_eq!(got.stats, want.stats, "job {} ({backend_kind:?})", job.id);
            assert_eq!(got.energy, want.energy, "job {} ({backend_kind:?})", job.id);
            assert_eq!(got.delay_cycles, want.delay_cycles, "job {} ({backend_kind:?})", job.id);
            check_against_host(job, &got);
        }
        let metrics = svc.shutdown();
        assert_eq!(metrics.search_jobs, jobs.len() as u64, "{backend_kind:?}");
        assert!(metrics.search_passes > 0, "{backend_kind:?}");
    }
}
