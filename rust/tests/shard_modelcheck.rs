//! Exhaustive model checking of the sharded coordinator (the PR-6
//! tentpole): every interleaving of bounded scenarios — producers
//! submitting jobs/programs, workers popping, batch deadlines expiring,
//! idle shards stealing, shutdown draining — is explored breadth-first
//! through [`mvap::modelcheck`], with the no-loss / no-duplication /
//! stats-conservation invariants checked in every reachable state and
//! eventual-flush liveness checked over the whole graph.
//!
//! The machine under test ([`ShardSystemMachine`]) drives the *same*
//! [`mvap::coordinator::ShardCore::on_event`] transition the threaded
//! `ShardedService` worker interprets, so these proofs are about the
//! production decision logic, not a parallel model (the threaded side is
//! exercised under real contention in `shard_stress.rs`).
//!
//! The expected state/transition/depth figures are pinned against an
//! independent Python port (`python/modelcheck_port.py`) that explored
//! the same scenarios under **every possible** signature→shard routing;
//! the ranges below are the exact min/max over that sweep, so a Rust
//! count outside them means the two implementations diverged.
//!
//! Fault-injection wrappers then verify the checker *catches* seeded
//! bugs — duplicated submissions, lost submissions, a shutdown that
//! never closes — each with a shortest (depth-minimal) counterexample
//! trace.

use mvap::coordinator::shard_machine::{ShardScenario, SysAction, SysState};
use mvap::coordinator::ShardSystemMachine;
use mvap::modelcheck::{explore, CheckFailure, ExploreConfig, Machine, Report, Violation};
use std::ops::RangeInclusive;

/// The bounded scenarios CI proves exhaustively, with the exact
/// state-count ranges from the all-routings Python sweep.
struct Bounded {
    label: &'static str,
    scenario: ShardScenario,
    states: RangeInclusive<usize>,
    transitions: RangeInclusive<usize>,
    depth: RangeInclusive<usize>,
}

fn bounded_scenarios() -> Vec<Bounded> {
    vec![
        Bounded {
            label: "2 shards, depth 2, batch 2, steal, 2 producers, 3 jobs (2 sigs) + 1 program",
            scenario: ShardScenario::mixed(2, 2, 2, true, 2, 3, 1, 2),
            states: 508..=605,
            transitions: 1540..=1822,
            depth: 11..=11,
        },
        Bounded {
            label: "3 shards, depth 2, batch 2, steal, 2 producers, 3 jobs (3 sigs) + 2 programs",
            scenario: ShardScenario::mixed(3, 2, 2, true, 2, 3, 2, 3),
            states: 4226..=5858,
            transitions: 17624..=24525,
            depth: 14..=14,
        },
        Bounded {
            label: "2 shards, depth 3, batch 3, no steal, 1 producer, 4 jobs (2 sigs) + 1 program",
            scenario: ShardScenario::mixed(2, 3, 3, false, 1, 4, 1, 2),
            states: 66..=274,
            transitions: 124..=765,
            depth: 13..=16,
        },
        Bounded {
            label: "2 shards, depth 2, batch 2, steal, 2 producers, 4 jobs (2 sigs) + 2 programs",
            scenario: ShardScenario::mixed(2, 2, 2, true, 2, 4, 2, 2),
            states: 2752..=2971,
            transitions: 8961..=9788,
            depth: 15..=15,
        },
    ]
}

/// Exhaustive exploration of every bounded scenario: all invariants hold
/// in every reachable state, the goal (everything flushed, workers
/// exited) is the unique terminal state, liveness holds, and the counts
/// land inside the Python-pinned ranges.
#[test]
fn bounded_scenarios_explore_clean() {
    for b in bounded_scenarios() {
        let m = ShardSystemMachine::new(b.scenario);
        let report: Report<ShardSystemMachine> = match explore(&m, &ExploreConfig::default()) {
            Ok(r) => r,
            Err(f) => panic!("{}: {}", b.label, f.render(&m)),
        };
        println!("{}: {}", b.label, report.summary());
        assert!(
            b.states.contains(&report.states),
            "{}: {} states outside pinned range {:?}",
            b.label,
            report.states,
            b.states
        );
        assert!(
            b.transitions.contains(&report.transitions),
            "{}: {} transitions outside pinned range {:?}",
            b.label,
            report.transitions,
            b.transitions
        );
        assert!(
            b.depth.contains(&report.depth),
            "{}: depth {} outside pinned range {:?}",
            b.label,
            report.depth,
            b.depth
        );
        assert_eq!(report.goals, 1, "{}: exactly one all-flushed goal state", b.label);
        assert_eq!(report.terminal, 1, "{}: the goal is the only terminal state", b.label);
    }
}

/// The tiny DOT scenario renders an inspectable state diagram of the
/// shard machine (this is the graph embedded in docs/ARCHITECTURE.md).
#[test]
fn dot_export_renders_the_shard_machine() {
    let m = ShardSystemMachine::new(ShardScenario::mixed(2, 2, 2, true, 1, 1, 1, 1));
    let cfg = ExploreConfig { record_graph: true, ..ExploreConfig::default() };
    let report = explore(&m, &cfg).expect("tiny scenario is clean");
    assert!((40..=42).contains(&report.states), "states={}", report.states);
    assert_eq!(report.depth, 7);
    let dot = report.dot(&m).expect("graph recorded");
    assert!(dot.starts_with("digraph explored {"));
    for i in 0..report.states {
        assert!(dot.contains(&format!("\"s{i}\"")), "node s{i} missing");
    }
    assert!(dot.contains("doublecircle"), "goal state must be styled");
    assert!(dot.contains("label=\"submit p0\""), "edges carry action labels");
    assert!(dot.contains("label=\"drain s"), "shutdown edges present");
}

// ---------------------------------------------------------------------------
// Fault injection: the checker must CATCH seeded coordinator bugs, with
// minimal traces. Each wrapper delegates to the real machine and breaks
// exactly one thing.
// ---------------------------------------------------------------------------

fn faultable() -> ShardSystemMachine {
    ShardSystemMachine::new(ShardScenario::mixed(2, 2, 2, true, 2, 3, 1, 2))
}

/// Finds item 0 in some queue of `st` (None if absent).
fn locate(st: &SysState, id: u8) -> Option<(usize, usize)> {
    st.queues
        .iter()
        .enumerate()
        .find_map(|(q, items)| items.iter().position(|&x| x == id).map(|i| (q, i)))
}

/// A submit path that enqueues the first submission twice (a retry bug).
struct DuplicatedSubmit(ShardSystemMachine);

impl Machine for DuplicatedSubmit {
    type State = SysState;
    type Action = SysAction;

    fn initial(&self) -> SysState {
        self.0.initial()
    }

    fn actions(&self, st: &SysState, out: &mut Vec<SysAction>) {
        self.0.actions(st, out);
    }

    fn transition(&self, st: &SysState, a: &SysAction) -> Result<SysState, Violation> {
        let mut next = self.0.transition(st, a)?;
        if matches!(a, SysAction::Submit { producer: 0 }) && st.produced[0] == 0 {
            let (q, _) = locate(&next, 0).expect("first submission is queued");
            next.queues[q].push(0); // the bug: enqueued twice
        }
        Ok(next)
    }

    fn invariant(&self, st: &SysState) -> Result<(), Violation> {
        self.0.invariant(st)
    }

    fn is_goal(&self, st: &SysState) -> bool {
        self.0.is_goal(st)
    }
}

#[test]
fn checker_catches_duplicated_submission() {
    let m = DuplicatedSubmit(faultable());
    let failure = *explore(&m, &ExploreConfig::default()).expect_err("must be caught");
    match failure {
        CheckFailure::Invariant { violation, trace } => {
            assert!(
                violation.message().contains("no-duplication"),
                "got: {violation}"
            );
            // minimal trace: the very first tampered submission
            assert_eq!(trace.len(), 1, "counterexample must be depth-minimal");
            let rendered = trace.render(&m);
            assert!(rendered.contains("submit p0"), "rendered: {rendered}");
        }
        other => panic!("expected invariant violation, got {}", other.headline()),
    }
}

/// A submit path that loses the first submission (enqueue dropped).
struct LostSubmit(ShardSystemMachine);

impl Machine for LostSubmit {
    type State = SysState;
    type Action = SysAction;

    fn initial(&self) -> SysState {
        self.0.initial()
    }

    fn actions(&self, st: &SysState, out: &mut Vec<SysAction>) {
        self.0.actions(st, out);
    }

    fn transition(&self, st: &SysState, a: &SysAction) -> Result<SysState, Violation> {
        let mut next = self.0.transition(st, a)?;
        if matches!(a, SysAction::Submit { producer: 0 }) && st.produced[0] == 0 {
            let (q, i) = locate(&next, 0).expect("first submission is queued");
            next.queues[q].remove(i); // the bug: item dropped on the floor
        }
        Ok(next)
    }

    fn invariant(&self, st: &SysState) -> Result<(), Violation> {
        self.0.invariant(st)
    }

    fn is_goal(&self, st: &SysState) -> bool {
        self.0.is_goal(st)
    }
}

#[test]
fn checker_catches_lost_submission() {
    let m = LostSubmit(faultable());
    let failure = *explore(&m, &ExploreConfig::default()).expect_err("must be caught");
    match failure {
        CheckFailure::Invariant { violation, trace } => {
            assert!(violation.message().contains("no-loss"), "got: {violation}");
            assert_eq!(trace.len(), 1, "counterexample must be depth-minimal");
        }
        other => panic!("expected invariant violation, got {}", other.headline()),
    }
}

/// A shutdown path that never closes the queues (Close action missing).
struct NeverCloses(ShardSystemMachine);

impl Machine for NeverCloses {
    type State = SysState;
    type Action = SysAction;

    fn initial(&self) -> SysState {
        self.0.initial()
    }

    fn actions(&self, st: &SysState, out: &mut Vec<SysAction>) {
        self.0.actions(st, out);
        out.retain(|a| !matches!(a, SysAction::Close));
    }

    fn transition(&self, st: &SysState, a: &SysAction) -> Result<SysState, Violation> {
        self.0.transition(st, a)
    }

    fn invariant(&self, st: &SysState) -> Result<(), Violation> {
        self.0.invariant(st)
    }

    fn is_goal(&self, st: &SysState) -> bool {
        self.0.is_goal(st)
    }
}

/// With the deadlock check on, the missing Close surfaces as a terminal
/// non-goal state (everything executed, nobody can exit).
#[test]
fn checker_catches_missing_close_as_deadlock() {
    let m = NeverCloses(faultable());
    let failure = *explore(&m, &ExploreConfig::default()).expect_err("must be caught");
    match failure {
        CheckFailure::Deadlock { trace } => {
            assert!(!trace.is_empty());
            assert!(!trace.last().closed, "the stuck state never closed");
        }
        other => panic!("expected deadlock, got {}", other.headline()),
    }
}

/// With the deadlock check off, the same bug is a liveness violation:
/// no reachable state can reach the all-flushed goal.
#[test]
fn checker_catches_missing_close_as_liveness_violation() {
    let m = NeverCloses(faultable());
    let cfg = ExploreConfig { check_deadlock: false, ..ExploreConfig::default() };
    let failure = *explore(&m, &cfg).expect_err("must be caught");
    match failure {
        CheckFailure::Liveness { trace } => {
            // the goal is unreachable from everywhere, so the minimal
            // counterexample is the initial state itself
            assert!(trace.is_empty(), "minimal liveness witness is the initial state");
        }
        other => panic!("expected liveness violation, got {}", other.headline()),
    }
}
