//! Differential property tests: the bit-sliced digit-plane backend is
//! *observably identical* to the scalar `CamArray` — same tags, same
//! mismatch histogram, same set/reset write-op counts, same stored
//! contents — across random radices (2–5), row counts (including
//! non-multiples of 64), mask widths, don't-care densities, and
//! interleaved compare/write rounds.

mod common;

use common::{random_digit, random_words};
use mvap::ap::{add_vectors, adder_lut, load_operands_storage, Ap, ExecMode};
use mvap::cam::{
    march_detect, BitSlicedArray, CamArray, CamStorage, Fault, FaultyArray, StorageKind,
};
use mvap::mvl::{Radix, DONT_CARE};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;

/// Random interleaved compare/write rounds on both backends; every
/// observable output must agree at every step.
#[test]
fn compare_write_rounds_agree() {
    forall(Config::cases(300), |rng: &mut Rng| {
        let n = 2 + rng.digit(4); // radix 2..=5
        let radix = Radix(n);
        // bias row counts toward word-boundary straddles
        let rows = match rng.index(4) {
            0 => 1 + rng.index(63),
            1 => 63 + rng.index(4),
            2 => 127 + rng.index(4),
            _ => 1 + rng.index(300),
        };
        let cols = 1 + rng.index(8);
        let mut data = vec![0u8; rows * cols];
        for d in data.iter_mut() {
            *d = random_digit(rng, n, 0.15);
        }
        let mut scalar = CamArray::from_data(radix, rows, cols, data.clone());
        let mut sliced = BitSlicedArray::from_data(radix, rows, cols, &data);

        for round in 0..3 {
            // masked compare over a random column subset
            let width = 1 + rng.index(cols);
            let mut all: Vec<usize> = (0..cols).collect();
            rng.shuffle(&mut all);
            let sel = &all[..width];
            let keys: Vec<u8> = (0..width).map(|_| random_digit(rng, n, 0.1)).collect();
            let a = scalar.compare(sel, &keys);
            let b = sliced.compare(sel, &keys);
            assert_eq!(a.tags, b.tags, "round {round}: tags (n={n} rows={rows})");
            assert_eq!(
                a.mismatch_hist, b.mismatch_hist,
                "round {round}: histogram (n={n} rows={rows} width={width})"
            );

            // tagged write into random columns (duplicates allowed — the
            // scalar semantics apply them in order) with random values,
            // including don't-care writes
            let ww = 1 + rng.index(cols);
            let wcols: Vec<usize> = (0..ww).map(|_| rng.index(cols)).collect();
            let vals: Vec<u8> = (0..ww).map(|_| random_digit(rng, n, 0.1)).collect();
            let ops_a = scalar.write(&a.tags, &wcols, &vals);
            let ops_b = sliced.write(&a.tags, &wcols, &vals);
            assert_eq!(ops_a, ops_b, "round {round}: write ops (n={n} rows={rows})");
            assert_eq!(
                scalar.data(),
                &sliced.to_digits()[..],
                "round {round}: contents (n={n} rows={rows})"
            );
        }
    });
}

/// Explicit word-boundary row counts, all radices 2–5: a full compare and
/// a full-width write must agree exactly.
#[test]
fn word_boundary_row_counts() {
    for n in 2u8..=5 {
        let radix = Radix(n);
        for rows in [1usize, 2, 63, 64, 65, 127, 128, 129, 191, 192, 193, 1000] {
            let mut rng = Rng::new(rows as u64 * 31 + n as u64);
            let cols = 4;
            let mut data = vec![0u8; rows * cols];
            for d in data.iter_mut() {
                *d = if rng.chance(0.2) { DONT_CARE } else { rng.digit(n) };
            }
            let mut scalar = CamArray::from_data(radix, rows, cols, data.clone());
            let mut sliced = BitSlicedArray::from_data(radix, rows, cols, &data);
            let keys: Vec<u8> = (0..cols).map(|_| rng.digit(n)).collect();
            let sel: Vec<usize> = (0..cols).collect();
            let a = scalar.compare(&sel, &keys);
            let b = sliced.compare(&sel, &keys);
            assert_eq!(a.tags, b.tags, "n={n} rows={rows}");
            assert_eq!(a.mismatch_hist, b.mismatch_hist, "n={n} rows={rows}");
            assert_eq!(
                a.mismatch_hist.iter().sum::<u64>(),
                rows as u64,
                "histogram mass n={n} rows={rows}"
            );
            let vals: Vec<u8> = (0..cols).map(|_| rng.digit(n)).collect();
            let ops_a = scalar.write(&a.tags, &sel, &vals);
            let ops_b = sliced.write(&a.tags, &sel, &vals);
            assert_eq!(ops_a, ops_b, "n={n} rows={rows}");
            assert_eq!(scalar.data(), &sliced.to_digits()[..], "n={n} rows={rows}");
        }
    }
}

/// All-don't-care keys and all-don't-care arrays: everything matches,
/// nothing mismatches, on both backends.
#[test]
fn degenerate_dont_care_cases() {
    let radix = Radix::TERNARY;
    let rows = 70;
    let scalar = CamArray::new(radix, rows, 3);
    let sliced = BitSlicedArray::new(radix, rows, 3);
    for keys in [vec![DONT_CARE, DONT_CARE], vec![0, 2]] {
        let a = scalar.compare(&[0, 2], &keys);
        let b = sliced.compare(&[0, 2], &keys);
        assert_eq!(a.tags, b.tags);
        assert_eq!(a.mismatch_hist, b.mismatch_hist);
        assert_eq!(a.mismatch_hist[0], rows as u64);
    }
}

/// Fault injection on the bit-sliced backend is observably identical to
/// the scalar backend: the same planted stuck faults produce the same
/// compare tags and mismatch histograms, the same priced write ops, the
/// same visible contents — and the march test locates the same cells.
#[test]
fn faulty_arrays_agree_across_storages() {
    forall(Config::cases(60), |rng: &mut Rng| {
        let n = 2 + rng.digit(4); // radix 2..=5
        let radix = Radix(n);
        // bias row counts toward 64-row word-boundary straddles
        let rows = match rng.index(3) {
            0 => 1 + rng.index(40),
            1 => 63 + rng.index(4),
            _ => 1 + rng.index(200),
        };
        let cols = 1 + rng.index(4);
        let mut data = vec![0u8; rows * cols];
        for d in data.iter_mut() {
            *d = random_digit(rng, n, 0.1);
        }
        let mut scalar = FaultyArray::with_storage(CamStorage::from_data(
            StorageKind::Scalar,
            radix,
            rows,
            cols,
            &data,
        ));
        let mut sliced = FaultyArray::with_storage(CamStorage::from_data(
            StorageKind::BitSliced,
            radix,
            rows,
            cols,
            &data,
        ));
        // plant identical faults on both
        for _ in 0..1 + rng.index(4) {
            let r = rng.index(rows);
            let c = rng.index(cols);
            let fault = if rng.chance(0.5) {
                Fault::StuckAtValue(rng.digit(n))
            } else {
                Fault::StuckDontCare
            };
            scalar.inject(r, c, fault);
            sliced.inject(r, c, fault);
        }
        assert_eq!(
            scalar.array().to_digits(),
            sliced.array().to_digits(),
            "fault-effective contents (n={n} rows={rows})"
        );
        // interleaved compare/write rounds must agree observably
        for round in 0..3 {
            let width = 1 + rng.index(cols);
            let mut all: Vec<usize> = (0..cols).collect();
            rng.shuffle(&mut all);
            let sel = &all[..width];
            let keys: Vec<u8> = (0..width).map(|_| random_digit(rng, n, 0.1)).collect();
            let a = scalar.compare(sel, &keys);
            let b = sliced.compare(sel, &keys);
            assert_eq!(a.tags, b.tags, "round {round}: tags (n={n} rows={rows})");
            assert_eq!(
                a.mismatch_hist, b.mismatch_hist,
                "round {round}: histogram (n={n} rows={rows})"
            );
            let ww = 1 + rng.index(cols);
            let wcols: Vec<usize> = (0..ww).map(|_| rng.index(cols)).collect();
            let vals: Vec<u8> = (0..ww).map(|_| rng.digit(n)).collect();
            let ops_a = scalar.write(&a.tags, &wcols, &vals);
            let ops_b = sliced.write(&b.tags, &wcols, &vals);
            assert_eq!(ops_a, ops_b, "round {round}: write ops (n={n} rows={rows})");
            assert_eq!(
                scalar.array().to_digits(),
                sliced.array().to_digits(),
                "round {round}: contents (n={n} rows={rows})"
            );
        }
        // march detection locates the same suspect cells on both backends
        assert_eq!(
            march_detect(&mut scalar),
            march_detect(&mut sliced),
            "march suspects (n={n} rows={rows})"
        );
    });
}

/// The march test detects planted faults exactly, on the bit-sliced
/// backend, across word-boundary row counts.
#[test]
fn bitsliced_march_detects_planted_faults() {
    let radix = Radix::TERNARY;
    for rows in [1usize, 63, 64, 65, 128] {
        let mut rng = Rng::new(rows as u64 * 17 + 1);
        let cols = 3;
        let mut a = FaultyArray::new_kind(StorageKind::BitSliced, radix, rows, cols);
        let mut planted = std::collections::BTreeSet::new();
        for _ in 0..1 + rng.index(3) {
            let r = rng.index(rows);
            let c = rng.index(cols);
            let fault = if rng.chance(0.5) {
                Fault::StuckAtValue(rng.digit(3))
            } else {
                Fault::StuckDontCare
            };
            a.inject(r, c, fault);
            planted.insert((r, c));
        }
        let found: std::collections::BTreeSet<(usize, usize)> =
            march_detect(&mut a).into_iter().collect();
        assert_eq!(found, planted, "rows={rows}");
    }
}

/// End-to-end LUT-program execution through `Ap` on both storage
/// backends: identical array contents and identical statistics, for both
/// execution modes, at radices 2–4.
#[test]
fn lut_programs_agree_across_storages() {
    forall(Config::cases(25), |rng: &mut Rng| {
        let radix = Radix(2 + rng.digit(3));
        let p = 1 + rng.index(8);
        let rows = 1 + rng.index(200);
        let a = random_words(rng, rows, p, radix);
        let b = random_words(rng, rows, p, radix);
        let mode = if rng.chance(0.5) { ExecMode::Blocked } else { ExecMode::NonBlocked };
        let lut = adder_lut(radix, mode);

        let run = |kind: StorageKind, rng_a: &[Word], rng_b: &[Word]| {
            let (storage, layout) = load_operands_storage(kind, radix, rng_a, rng_b, None);
            let mut ap = Ap::with_storage(storage);
            let values = add_vectors(&mut ap, &layout, &lut, mode);
            (values, ap.take_stats(), ap.storage().to_digits())
        };
        let (v1, s1, d1) = run(StorageKind::Scalar, &a, &b);
        let (v2, s2, d2) = run(StorageKind::BitSliced, &a, &b);
        assert_eq!(v1, v2, "values (radix={} rows={rows} {mode:?})", radix.n());
        assert_eq!(s1, s2, "stats (radix={} rows={rows} {mode:?})", radix.n());
        assert_eq!(d1, d2, "contents (radix={} rows={rows} {mode:?})", radix.n());

        // and the oracle still holds on the bit-sliced path
        for r in 0..rows {
            let (expect, cout) = a[r].add_ref(&b[r], 0);
            assert_eq!(v2[r].0, expect, "row {r}");
            assert_eq!(v2[r].1, cout, "row {r}");
        }
    });
}
