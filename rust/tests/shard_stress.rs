//! Threaded stress test for the sharded coordinator: N producer threads
//! hammer one `ShardedService` with a mixed stream of coalescable jobs
//! and standalone dataflow programs under real contention — tiny queues
//! for backpressure, microsecond flush deadlines so timeout flushes race
//! submissions, stealing on and off — then every oracle and the
//! no-loss / no-duplication / stats-conservation invariants are checked.
//!
//! This is the effectful complement of `shard_modelcheck.rs`: the model
//! checker proves the decision core correct over every interleaving of
//! bounded scenarios; this test drives the *real* threaded worker (which
//! interprets that same core) through OS-scheduled interleavings with
//! real payloads, channels, and engines.
//!
//! Replay a failing case with `MVAP_PROP_SEED=0x… cargo test -q --test
//! shard_stress` (the seed is printed in the failure message).

use mvap::coordinator::{Job, NativeBackend, OpKind, ShardConfig, ShardedService, SubmitError};
use mvap::mvl::{Radix, Word};
use mvap::program::{builtin, reference, BoundProgram};
use mvap::util::prop::{forall, Config};
use mvap::util::Rng;
use std::sync::Arc;
use std::time::Duration;

/// A wait long enough that only a genuinely lost reply can trip it; a
/// timeout here means a submission was dropped (no-loss violated).
const LOST: Duration = Duration::from_secs(30);

fn add_job(id: u64, rng: &mut Rng, rows: usize, p: usize) -> (Job, Vec<(Word, u8)>) {
    let radix = Radix::TERNARY;
    let a: Vec<Word> = (0..rows).map(|_| Word::from_digits(rng.number(p, 3), radix)).collect();
    let b: Vec<Word> = (0..rows).map(|_| Word::from_digits(rng.number(p, 3), radix)).collect();
    let expect = a.iter().zip(&b).map(|(x, y)| x.add_ref(y, 0)).collect();
    (Job::new(id, OpKind::Add, radix, true, a, b), expect)
}

/// Mixed producers × random shard configs: every job and program result
/// matches its oracle (no loss, no corruption), and the aggregate metrics
/// conserve the workload exactly (no duplication: each submission is
/// executed exactly once, solo or coalesced, home or stolen).
#[test]
fn producers_race_submissions_against_flushes_and_steals() {
    forall(Config::cases(4), |rng| {
        let cfg = ShardConfig {
            shards: 2 + rng.index(3),
            queue_depth: 2 + rng.index(7),
            max_batch_jobs: 1 + rng.index(8),
            max_batch_rows: 64 + rng.index(512),
            // microsecond-scale deadlines: timeout flushes race the
            // producers instead of waiting them out
            flush_after: Duration::from_micros(50 + rng.next_u64() % 450),
            steal: rng.chance(0.5),
            parallelism: mvap::cam::Parallelism::sequential(),
        };
        let producers = 2 + rng.index(3);
        let per_producer = 6 + rng.index(5);
        let svc = ShardedService::start(cfg, || {
            Ok(Box::new(NativeBackend::default()) as _)
        })
        .unwrap();
        let plan = Arc::new(builtin::dot(Radix::TERNARY, 4).plan());

        let seeds: Vec<u64> = (0..producers).map(|_| rng.next_u64()).collect();
        let totals: (u64, u64) = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (p, seed) in seeds.into_iter().enumerate() {
                let svc = &svc;
                let plan = Arc::clone(&plan);
                handles.push(scope.spawn(move || {
                    let mut rng = Rng::new(seed);
                    let mut jobs = 0u64;
                    let mut programs = 0u64;
                    let mut job_rx = Vec::new();
                    let mut prog_rx = Vec::new();
                    for i in 0..per_producer {
                        let id = (p * 1000 + i) as u64;
                        if rng.chance(0.3) {
                            // a standalone dot program (barrier-flushes
                            // whatever batch its shard is collecting)
                            let rows = 1 + rng.index(30);
                            let mk = |rng: &mut Rng| -> Vec<Word> {
                                (0..rows)
                                    .map(|_| {
                                        Word::from_digits(rng.number(4, 3), Radix::TERNARY)
                                    })
                                    .collect()
                            };
                            let (a, b) = (mk(&mut rng), mk(&mut rng));
                            let want = reference::evaluate(
                                plan.program(),
                                &[("a", a.clone()), ("b", b.clone())],
                            );
                            let bound =
                                BoundProgram::bind(&plan, vec![("a", a), ("b", b)], true)
                                    .unwrap();
                            prog_rx.push((
                                svc.submit_program(bound).expect("service open"),
                                want,
                            ));
                            programs += 1;
                        } else {
                            // few distinct digit widths → few signatures →
                            // cross-producer coalescing on shared shards
                            let digits = 3 + 2 * rng.index(2);
                            let rows = 1 + rng.index(60);
                            let (job, expect) = add_job(id, &mut rng, rows, digits);
                            job_rx.push((svc.submit(job).expect("service open"), id, expect));
                            jobs += 1;
                        }
                    }
                    for (rx, id, expect) in job_rx {
                        let res = rx
                            .recv_timeout(LOST)
                            .unwrap_or_else(|_| panic!("job {id} reply lost"))
                            .unwrap();
                        assert_eq!(res.id, id);
                        assert_eq!(res.values, expect, "job {id} corrupted");
                    }
                    for (i, (rx, want)) in prog_rx.into_iter().enumerate() {
                        let report = rx
                            .recv_timeout(LOST)
                            .unwrap_or_else(|_| panic!("producer {p} program {i} reply lost"))
                            .unwrap();
                        assert_eq!(report.outputs, want, "producer {p} program {i} corrupted");
                    }
                    (jobs, programs)
                }));
            }
            handles.into_iter().map(|h| h.join().expect("producer panicked")).fold(
                (0, 0),
                |(j, pr), (dj, dpr)| (j + dj, pr + dpr),
            )
        });
        let (jobs, programs) = totals;
        assert_eq!(jobs + programs, (producers * per_producer) as u64);

        // stats conservation across the whole service: each submission
        // executed exactly once, nothing double-counted, per-shard
        // metrics partition the totals
        let (agg, per_shard) = svc.shutdown();
        assert_eq!(agg.jobs, jobs + programs, "every submission executed exactly once");
        assert_eq!(agg.programs, programs);
        assert_eq!(agg.solo_jobs + agg.coalesced_jobs, jobs, "jobs ran solo xor coalesced");
        assert_eq!(per_shard.len(), cfg.shards);
        assert_eq!(per_shard.iter().map(|m| m.jobs).sum::<u64>(), agg.jobs);
        assert_eq!(per_shard.iter().map(|m| m.programs).sum::<u64>(), agg.programs);
        assert_eq!(per_shard.iter().map(|m| m.rows).sum::<u64>(), agg.rows);
        assert!(agg.stolen_jobs <= agg.jobs);
        if !cfg.steal {
            assert_eq!(agg.stolen_jobs, 0, "stealing disabled");
        }
    });
}

/// The submit-after-shutdown race this PR de-panics: producers hammer
/// `submit` while the main thread closes the service mid-stream. Before
/// the fix this was an `assert!` panic inside the queue; now racing
/// producers get `Err(SubmitError::Closed)`, and the drain-before-Closed
/// guarantee still delivers a correct reply for everything accepted.
#[test]
fn close_races_active_producers_without_panicking() {
    forall(Config::cases(3), |rng| {
        let cfg = ShardConfig {
            shards: 2,
            queue_depth: 2 + rng.index(3),
            max_batch_jobs: 4,
            max_batch_rows: 256,
            flush_after: Duration::from_micros(200),
            steal: rng.chance(0.5),
            parallelism: mvap::cam::Parallelism::sequential(),
        };
        let svc = ShardedService::start(cfg, || {
            Ok(Box::new(NativeBackend::default()) as _)
        })
        .unwrap();
        let producers = 2 + rng.index(3);
        let seeds: Vec<u64> = (0..producers).map(|_| rng.next_u64()).collect();
        let close_after = Duration::from_micros(500 + rng.next_u64() % 2000);
        let accepted: u64 = std::thread::scope(|scope| {
            let handles: Vec<_> = seeds
                .into_iter()
                .enumerate()
                .map(|(p, seed)| {
                    let svc = &svc;
                    scope.spawn(move || {
                        let mut rng = Rng::new(seed);
                        let mut accepted = Vec::new();
                        for i in 0..200u64 {
                            let id = ((p as u64) << 32) | i;
                            let rows = 1 + rng.index(8);
                            let (job, expect) = add_job(id, &mut rng, rows, 4);
                            match svc.submit(job) {
                                Ok(rx) => accepted.push((rx, id, expect)),
                                Err(SubmitError::Closed) => break,
                                Err(e) => panic!("unexpected submit error: {e}"),
                            }
                        }
                        let n = accepted.len() as u64;
                        for (rx, id, expect) in accepted {
                            let res = rx
                                .recv_timeout(LOST)
                                .unwrap_or_else(|_| panic!("job {id} lost across close"))
                                .unwrap();
                            assert_eq!(res.values, expect, "job {id}");
                        }
                        n
                    })
                })
                .collect();
            // let the producers build up steam, then slam the door
            std::thread::sleep(close_after);
            svc.close();
            handles.into_iter().map(|h| h.join().expect("producer panicked")).sum()
        });
        // conservation across the race: exactly the accepted submissions
        // executed — none lost in the close, none executed twice
        let (agg, _) = svc.shutdown();
        assert_eq!(agg.jobs, accepted, "accepted-before-close equals executed");
    });
}

/// Shutdown during a drain race: close the service the moment the last
/// submission is accepted. The drain-before-Closed queue guarantee means
/// every reply must still arrive.
#[test]
fn shutdown_races_inflight_work_without_loss() {
    forall(Config::cases(3), |rng| {
        let cfg = ShardConfig {
            shards: 2 + rng.index(2),
            queue_depth: 2,
            max_batch_jobs: 4,
            max_batch_rows: 256,
            // long deadline: pending batches at shutdown only flush
            // because Closed flushes them, not because time ran out
            flush_after: Duration::from_millis(200),
            steal: rng.chance(0.5),
            parallelism: mvap::cam::Parallelism::sequential(),
        };
        let svc = ShardedService::start(cfg, || {
            Ok(Box::new(NativeBackend::default()) as _)
        })
        .unwrap();
        let n = 6 + rng.index(8);
        let mut pending = Vec::new();
        for id in 0..n as u64 {
            let rows = 1 + rng.index(20);
            let (job, expect) = add_job(id, rng, rows, 4);
            pending.push((svc.submit(job).expect("service open"), id, expect));
        }
        // immediate shutdown: queued + batched work must drain, not drop
        let (agg, _) = svc.shutdown();
        for (rx, id, expect) in pending {
            let res = rx
                .recv_timeout(LOST)
                .unwrap_or_else(|_| panic!("job {id} lost in shutdown drain"))
                .unwrap();
            assert_eq!(res.values, expect, "job {id}");
        }
        assert_eq!(agg.jobs, n as u64);
        assert_eq!(agg.solo_jobs + agg.coalesced_jobs, n as u64);
    });
}
