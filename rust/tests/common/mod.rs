//! Shared property-test helpers for the integration suites.
//!
//! Every suite drives randomness through [`mvap::util::prop::forall`], so
//! a failing case always prints its replay seed (`MVAP_PROP_SEED=0x…`);
//! these helpers keep the *samplers* identical across suites too — the
//! same radix ranges, the same word-boundary-biased row counts, the same
//! don't-care densities — so a distribution fix lands everywhere at once.

// Each test binary compiles its own copy of this module and uses only a
// subset of the helpers.
#![allow(dead_code)]

use mvap::cam::{CamStorage, StorageKind};
use mvap::coordinator::{JobSignature, OpKind};
use mvap::mvl::{Radix, Word, DONT_CARE};
use mvap::util::Rng;

/// Both storage backends, for `for kind in KINDS` sweeps.
pub const KINDS: [StorageKind; 2] = [StorageKind::Scalar, StorageKind::BitSliced];

/// A random radix in 2..=5 (every radix the paper's LUT zoo covers).
pub fn random_radix(rng: &mut Rng) -> Radix {
    Radix(2 + rng.digit(4))
}

/// A random radix in 2..=`hi` (some sweeps cap at 4 to bound LUT sizes).
pub fn random_radix_upto(rng: &mut Rng, hi: u8) -> Radix {
    assert!((2..=9).contains(&hi));
    Radix(2 + rng.digit(hi - 1))
}

/// A random digit in `0..n`, replaced by [`DONT_CARE`] with probability
/// `dont_care_p`.
pub fn random_digit(rng: &mut Rng, n: u8, dont_care_p: f64) -> u8 {
    if rng.chance(dont_care_p) {
        DONT_CARE
    } else {
        rng.digit(n)
    }
}

/// `rows` random `p`-digit words at `radix`.
pub fn random_words(rng: &mut Rng, rows: usize, p: usize, radix: Radix) -> Vec<Word> {
    (0..rows)
        .map(|_| Word::from_digits(rng.number(p, radix.n()), radix))
        .collect()
}

/// Row counts biased onto 64-row plane-word boundaries (1, 63–66,
/// 127–130) with a uniform tail up to 300 — the straddle cases where
/// bit-sliced masking bugs live.
pub fn boundary_rows(rng: &mut Rng) -> usize {
    match rng.index(4) {
        0 => 1 + rng.index(62),
        1 => 63 + rng.index(4),
        2 => 127 + rng.index(4),
        _ => 1 + rng.index(300),
    }
}

/// A `rows × cols` digit buffer at `radix` with the given don't-care
/// density.
pub fn random_data(
    rng: &mut Rng,
    rows: usize,
    cols: usize,
    radix: Radix,
    dont_care_p: f64,
) -> Vec<u8> {
    (0..rows * cols).map(|_| random_digit(rng, radix.n(), dont_care_p)).collect()
}

/// The differential-test harness: the same digit buffer loaded into both
/// storage backends, returned `(scalar, bit_sliced)`.
pub fn storage_pair(radix: Radix, rows: usize, cols: usize, data: &[u8]) -> (CamStorage, CamStorage) {
    (
        CamStorage::from_data(StorageKind::Scalar, radix, rows, cols, data),
        CamStorage::from_data(StorageKind::BitSliced, radix, rows, cols, data),
    )
}

/// A ternary blocked Add [`JobSignature`] with the given digit width —
/// distinct widths give distinct signatures (and thus distinct home
/// shards), which is all the coordinator tests need.
pub fn sig_with_digits(digits: usize) -> JobSignature {
    JobSignature {
        op: OpKind::Add,
        radix: Radix::TERNARY,
        blocked: true,
        digits,
        fold_rounds: 0,
    }
}
