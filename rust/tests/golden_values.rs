//! Golden-value pins for the paper's headline numbers, so refactors to
//! the stats/energy plumbing (e.g. the coalescing and segment-attribution
//! work) cannot silently drift the Table reproductions.
//!
//! Everything pinned here is either deterministic (LUT shapes, cycle
//! counts, model constants, normalized areas) or seeded-deterministic
//! with a tolerance anchored on the paper's published value.

use mvap::ap::{adder_lut, host_extreme, host_extreme_passes, ExecMode};
use mvap::coordinator::{Job, NativeBackend, VectorEngine};
use mvap::diagram::StateDiagram;
use mvap::energy::{
    area_normalized, delay_cycles, CompareEnergy, DelayScheme, EnergyModel, OpShape,
};
use mvap::exp::table11;
use mvap::func::{addc, copy_digit, full_sub, mac4, TruthTable};
use mvap::lutgen::{generate_blocked, generate_non_blocked};
use mvap::mvl::{Radix, Word};

/// Tables VII/X: the ternary full adder compiles to 21 passes, grouped
/// into 9 write blocks when blocked; Table VI: the binary adder of [6] is
/// 4 passes.
#[test]
fn golden_lut_shapes() {
    let nb = adder_lut(Radix::TERNARY, ExecMode::NonBlocked);
    assert_eq!(nb.passes.len(), 21, "Table VII pass count");
    assert_eq!(nb.num_groups, 21);
    let b = adder_lut(Radix::TERNARY, ExecMode::Blocked);
    assert_eq!(b.passes.len(), 21, "Table X pass count");
    assert_eq!(b.num_groups, 9, "Table X write blocks");
    assert_eq!(b.no_action.len(), 6, "TFA noAction states");
    let bin = adder_lut(Radix::BINARY, ExecMode::NonBlocked);
    assert_eq!(bin.passes.len(), 4, "Table VI pass count");
}

/// §VI-C delay: 20-trit addition is 840 cycles non-blocked and 600
/// blocked (1.4× saving); the 32-bit binary AP adder is 256 cycles, so
/// ternary blocked saves 2.34× ("2.3x" in the paper).
#[test]
fn golden_delay_cycles() {
    let nb = adder_lut(Radix::TERNARY, ExecMode::NonBlocked);
    let b = adder_lut(Radix::TERNARY, ExecMode::Blocked);
    let bin = adder_lut(Radix::BINARY, ExecMode::NonBlocked);
    let d_nb = delay_cycles(OpShape::of(&nb, 20), DelayScheme::Traditional);
    let d_b = delay_cycles(OpShape::of(&b, 20), DelayScheme::Traditional);
    let d_bin = delay_cycles(OpShape::of(&bin, 32), DelayScheme::Traditional);
    assert_eq!(d_nb, 840);
    assert_eq!(d_b, 600);
    assert_eq!(d_bin, 256);
    assert!((d_nb as f64 / d_b as f64 - 1.4).abs() < 1e-9, "blocked saving");
    assert!((d_b as f64 / d_bin as f64 - 2.34).abs() < 0.01, "vs binary AP");
}

/// The §VI-A compare-energy tables (our HSPICE substitute's outputs) and
/// the 1 nJ write-op constant [26] — the inputs to every energy figure.
#[test]
fn golden_energy_model_constants() {
    let t = CompareEnergy::default_ternary();
    assert_eq!(t.by_class, vec![3.60e-15, 18.49e-15, 25.66e-15, 29.05e-15]);
    let b = CompareEnergy::default_binary();
    assert_eq!(b.by_class, vec![1.85e-15, 17.65e-15, 25.26e-15, 28.86e-15]);
    assert_eq!(EnergyModel::ternary_default().write_op_energy, 1e-9);
    assert_eq!(EnergyModel::binary_default().write_op_energy, 1e-9);
}

/// The multiplication LUT family (§IV-B: mac4 partial-product kernel,
/// addc carry absorber, copy refresh — the programs behind
/// [`mvap::ap::mul_vectors`]): state/noAction/pass counts, blocked write
/// blocks, and cycle-breaking rewrite counts, pinned so lutgen or diagram
/// refactors cannot silently change the compiled programs. (The adder
/// family above was pinned in PR 2; this extends the pins to the mul
/// family.) Pass counts are mode-invariant — blocking regroups passes,
/// it never adds or removes them.
#[test]
fn golden_mul_family_lut_shapes() {
    // (states, noAction roots, passes, blocked write blocks, rewrites)
    let shape = |t: TruthTable| {
        let d = StateDiagram::build(t).unwrap();
        let nb = generate_non_blocked(&d);
        let b = generate_blocked(&d);
        assert_eq!(nb.passes.len(), b.passes.len(), "{}: pass count is mode-invariant", b.name);
        assert_eq!(nb.num_groups, nb.passes.len(), "{}: non-blocked = one block per pass", nb.name);
        (
            d.nodes().len(),
            d.roots().len(),
            b.passes.len(),
            b.num_groups,
            d.rewrites().len(),
        )
    };
    // ternary mac4: 24 of 81 states are fixed points; one (S,C) accumulator
    // cycle is broken with a widened write; 57 passes pack into 22 blocks
    assert_eq!(shape(mac4(Radix::TERNARY)), (81, 24, 57, 22, 1));
    // carry absorber and column copy are cycle-free forests
    assert_eq!(shape(addc(Radix::TERNARY)), (9, 3, 6, 4, 0));
    assert_eq!(shape(copy_digit(Radix::TERNARY)), (9, 3, 6, 3, 0));
    // binary and quaternary mac4 (the mul differential test radices)
    assert_eq!(shape(mac4(Radix::BINARY)), (16, 8, 8, 5, 0));
    assert_eq!(shape(mac4(Radix(4))), (256, 48, 208, 55, 4));
}

/// The subtraction LUT family (§I lists subtraction among the supported
/// functions; [`mvap::coordinator::OpKind::Sub`] and the program
/// subsystem's `Sub` element-wise op compile these): state/noAction/pass
/// counts, blocked write blocks, and cycle-breaking rewrite counts for
/// radix 2–5, pinned like the adder (PR 2) and mul (PR 4) families so
/// lutgen/diagram refactors cannot silently change the compiled programs.
/// Derived with the same calibrated Python re-implementation of
/// diagram+lutgen as the PR 4 pins (`python/compile/luts.py`, which
/// reproduces the adder's (27, 6, 21, 9, 1) and the binary adder's 4
/// passes exactly). The subtractor has markedly fewer fixed points than
/// the adder (only `(a, 0, 0)` states and borrow-stable corners), so
/// nearly every state needs a pass, and its borrow dynamics contain more
/// cycles (4 rewrites at radix 3 vs the adder's 1).
#[test]
fn golden_sub_family_lut_shapes() {
    // (states, noAction roots, passes, blocked write blocks, rewrites)
    let shape = |t: TruthTable| {
        let d = StateDiagram::build(t).unwrap();
        let nb = generate_non_blocked(&d);
        let b = generate_blocked(&d);
        assert_eq!(nb.passes.len(), b.passes.len(), "{}: pass count is mode-invariant", b.name);
        assert_eq!(nb.num_groups, nb.passes.len(), "{}: non-blocked = one block per pass", nb.name);
        (
            d.nodes().len(),
            d.roots().len(),
            b.passes.len(),
            b.num_groups,
            d.rewrites().len(),
        )
    };
    assert_eq!(shape(full_sub(Radix::BINARY)), (8, 2, 6, 6, 2));
    assert_eq!(shape(full_sub(Radix::TERNARY)), (27, 5, 22, 9, 4));
    assert_eq!(shape(full_sub(Radix(4))), (64, 5, 59, 14, 8));
    assert_eq!(shape(full_sub(Radix(5))), (125, 7, 118, 18, 12));
}

/// Min/Max elimination-schedule pins over the shared deterministic
/// fixture `values[r] = (r·37 + 11) mod radix⁴` (48 rows × 4 digits,
/// little-endian), radix 2–5: compare-pass counts, the accumulated
/// match/mismatch histogram, modeled delay (= passes; search ops never
/// write), and compare energy priced from the radix-appropriate §VI-A
/// table. The numbers are derived by the exact Python port
/// (`python/search_port.py` — run it to print all eight pins;
/// `python/tests/test_search_port.py::test_golden_pins` asserts the same
/// table), so a schedule drift in either language breaks one suite or
/// the other. Run through the engine job path so delay and energy
/// pricing are pinned end to end, on both native storage backends.
#[test]
fn golden_search_elimination_pins() {
    // radix -> (min, max), each (passes, [full matches, mismatches])
    let pins: [(u8, [(u64, [u64; 2]); 2]); 4] = [
        (2, [(4, [96, 96]), (4, [96, 96])]),
        (3, [(3, [47, 97]), (4, [63, 129])]),
        (4, [(5, [61, 179]), (4, [49, 143])]),
        (5, [(5, [50, 190]), (6, [54, 234])]),
    ];
    for (n, pin) in pins {
        let radix = Radix(n);
        let span = (n as u128).pow(4);
        let values: Vec<Word> = (0..48)
            .map(|r| Word::from_u128((r as u128 * 37 + 11) % span, 4, radix))
            .collect();
        let table = if n == 2 {
            CompareEnergy::default_binary()
        } else {
            CompareEnergy::default_ternary()
        };
        for (largest, (passes, hist)) in [false, true].into_iter().zip(pin) {
            // the schedule pin agrees with the host oracle's simulation
            assert_eq!(host_extreme_passes(&values, largest), passes, "radix {n}");
            for backend in [NativeBackend::default(), NativeBackend::bit_sliced()] {
                let mut eng = VectorEngine::new(Box::new(backend));
                let job = if largest {
                    Job::max(1, radix, values.clone(), vec![])
                } else {
                    Job::min(1, radix, values.clone(), vec![])
                };
                let res = eng.execute(&job).unwrap();
                let tag = format!("radix {n} largest={largest}");
                assert_eq!(res.hits.len(), 1, "{tag}");
                assert_eq!(res.hits[0].rows, host_extreme(&values, largest), "{tag}");
                assert_eq!(res.hits[0].passes, passes, "{tag}: pass count");
                assert_eq!(res.stats.compare_cycles, passes, "{tag}");
                assert_eq!(res.stats.mismatch_hist, hist.to_vec(), "{tag}: histogram");
                assert_eq!(res.delay_cycles, passes, "{tag}: delay = compare passes");
                assert_eq!(res.stats.write_cycles, 0, "{tag}: search never writes");
                assert_eq!(res.energy.write, 0.0, "{tag}");
                assert_eq!(res.energy.write_ops, 0, "{tag}");
                let want_compare =
                    hist[0] as f64 * table.by_class[0] + hist[1] as f64 * table.by_class[1];
                assert!(
                    (res.energy.compare - want_compare).abs() < 1e-24,
                    "{tag}: compare energy {} != {want_compare}",
                    res.energy.compare
                );
            }
        }
    }
}

/// Table XI normalized areas for every width pairing, and the 6.25%
/// saving at the 32b/20t design point (paper: 6.2%).
#[test]
fn golden_normalized_areas() {
    let expect = [
        (8usize, 5usize, 16.0, 15.0),
        (16, 10, 32.0, 30.0),
        (32, 20, 64.0, 60.0),
        (51, 32, 102.0, 96.0),
        (64, 40, 128.0, 120.0),
        (128, 80, 256.0, 240.0),
    ];
    assert_eq!(table11::PAIRINGS.map(|(q, _)| q), expect.map(|(q, ..)| q));
    for (q, p, eb, et) in expect {
        assert_eq!(area_normalized(q, 2), eb, "binary {q}b");
        assert_eq!(area_normalized(p, 3), et, "ternary {p}t");
    }
    let saving = 1.0 - area_normalized(20, 3) / area_normalized(32, 2);
    assert!((saving - 0.0625).abs() < 1e-9);
}

/// Table XI headline aggregates over the full pairing matrix (seeded
/// functional simulation): ternary saves ~12.6% set/reset ops, ~12.25%
/// energy, ~6.2% area vs the binary AP.
#[test]
fn golden_table11_headline_savings() {
    let results = table11::run(1500, 42);
    let (_, _, d_sets, d_energy, d_area) = table11::render(&results);
    assert!((0.08..=0.17).contains(&d_sets), "sets saving {d_sets} (paper 12.6%)");
    assert!((0.08..=0.17).contains(&d_energy), "energy saving {d_energy} (paper 12.25%)");
    assert!((0.055..=0.07).contains(&d_area), "area saving {d_area} (paper 6.2%)");
}

/// Table XI per-point anchors: the paper reports 5.99 set ops per 8-bit
/// binary addition and 5.22 per 5-trit ternary addition; write energy is
/// 2 × sets × 1 nJ (sets == resets).
#[test]
fn golden_sets_per_add_anchors() {
    let b = table11::measure(Radix::BINARY, 8, 4000, 7);
    assert!((b.sets_per_add - 5.99).abs() < 0.35, "8b sets/add {}", b.sets_per_add);
    assert!((b.write_energy - 2.0 * b.sets_per_add * 1e-9).abs() < 1e-12);
    let t = table11::measure(Radix::TERNARY, 5, 4000, 7);
    assert!((t.sets_per_add - 5.22).abs() < 0.35, "5t sets/add {}", t.sets_per_add);
}
