//! Minimal benchmark harness (criterion is not in the offline crate set):
//! warmup + timed iterations, reporting mean / p50 / p95 and a derived
//! throughput where the bench provides an item count. Supports a quick
//! mode (`--quick`: one warmup pass, few iterations — the CI trajectory
//! recorder) and machine-readable JSON output per summary.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Quick mode: minimal warmup and iteration counts, for CI trend
/// recording rather than low-noise measurement.
static QUICK: AtomicBool = AtomicBool::new(false);

/// Enable/disable quick mode (see [`bench`]).
pub fn set_quick(on: bool) {
    QUICK.store(on, Ordering::Relaxed);
}

fn quick() -> bool {
    QUICK.load(Ordering::Relaxed)
}

/// One benchmark's timing summary.
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// items/second if the bench declared a per-iteration item count.
    pub throughput: Option<f64>,
}

impl Summary {
    pub fn print(&self) {
        let tp = self
            .throughput
            .map(|t| format!("  {:>12.0} items/s", t))
            .unwrap_or_default();
        println!(
            "{:<38} {:>5} iters  mean {:>11?}  p50 {:>11?}  p95 {:>11?}{tp}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }

    /// One JSON object (no external serializer in the offline crate set).
    pub fn json(&self) -> String {
        let tp = self
            .throughput
            .map(|t| format!("{t:.1}"))
            .unwrap_or_else(|| "null".to_string());
        format!(
            "{{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \
             \"p95_ns\": {}, \"throughput_items_per_s\": {}}}",
            self.name,
            self.iters,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
            tp,
        )
    }
}

/// Run a benchmark: `f` is called once per iteration; `items` (optional)
/// is the per-iteration workload size for throughput reporting.
pub fn bench<F: FnMut()>(name: &str, items: Option<u64>, mut f: F) -> Summary {
    // Warmup: run until 0.3 s or 3 iterations, whichever is later
    // (quick mode: a single pass).
    let warm_start = Instant::now();
    let mut warm_iters = 0;
    while warm_iters < 3 || warm_start.elapsed() < Duration::from_millis(300) {
        f();
        warm_iters += 1;
        if quick() || warm_iters >= 50 {
            break;
        }
    }
    // Measure: aim for ~1.5 s of samples, 5..=200 iterations (quick
    // mode: exactly 3 — enough for a p50 trend line, cheap enough for CI).
    let per_iter = warm_start.elapsed() / warm_iters as u32;
    let target = Duration::from_millis(1500);
    let iters = if quick() {
        3
    } else {
        (target.as_nanos() / per_iter.as_nanos().max(1)).clamp(5, 200) as usize
    };
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let p50 = samples[iters / 2];
    let p95 = samples[(iters * 95 / 100).min(iters - 1)];
    let throughput = items.map(|n| n as f64 / mean.as_secs_f64());
    Summary { name: name.to_string(), iters, mean, p50, p95, throughput }
}

/// Prevent the optimiser from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
