//! Minimal benchmark harness (criterion is not in the offline crate set):
//! warmup + timed iterations, reporting mean / p50 / p95 and a derived
//! throughput where the bench provides an item count.

use std::time::{Duration, Instant};

/// One benchmark's timing summary.
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// items/second if the bench declared a per-iteration item count.
    pub throughput: Option<f64>,
}

impl Summary {
    pub fn print(&self) {
        let tp = self
            .throughput
            .map(|t| format!("  {:>12.0} items/s", t))
            .unwrap_or_default();
        println!(
            "{:<38} {:>5} iters  mean {:>11?}  p50 {:>11?}  p95 {:>11?}{tp}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }
}

/// Run a benchmark: `f` is called once per iteration; `items` (optional)
/// is the per-iteration workload size for throughput reporting.
pub fn bench<F: FnMut()>(name: &str, items: Option<u64>, mut f: F) -> Summary {
    // Warmup: run until 0.3 s or 3 iterations, whichever is later.
    let warm_start = Instant::now();
    let mut warm_iters = 0;
    while warm_iters < 3 || warm_start.elapsed() < Duration::from_millis(300) {
        f();
        warm_iters += 1;
        if warm_iters >= 50 {
            break;
        }
    }
    // Measure: aim for ~1.5 s of samples, 5..=200 iterations.
    let per_iter = warm_start.elapsed() / warm_iters as u32;
    let target = Duration::from_millis(1500);
    let iters = (target.as_nanos() / per_iter.as_nanos().max(1)).clamp(5, 200) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    let p50 = samples[iters / 2];
    let p95 = samples[(iters * 95 / 100).min(iters - 1)];
    let throughput = items.map(|n| n as f64 / mean.as_secs_f64());
    Summary { name: name.to_string(), iters, mean, p50, p95, throughput }
}

/// Prevent the optimiser from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
