//! `cargo bench` — one benchmark per paper table/figure (the regeneration
//! cost of each experiment) plus the hot-path microbenches the §Perf pass
//! optimises. Hand-rolled harness (criterion unavailable offline).
//!
//! Filter with `cargo bench -- <substring>...` (several substrings run
//! every bench matching any of them). Extra flags:
//!
//! * `--quick` — single warmup pass, 3 iterations per bench (the CI
//!   trajectory mode; see `ci.sh`, which records `BENCH_3.json` with it).
//! * `--json <path>` — additionally write the summaries as JSON.

mod harness;

use harness::{bench, black_box};
use mvap::ap::{
    add_vectors, adder_lut, load_operands, Ap, ApArena, ExecMode, KernelCache, LutKernel,
};
use mvap::cam::{BitSlicedArray, CamArray, Parallelism, StorageKind};
use mvap::circuit::{CellTech, MatchClass, MatchlineSim};
use mvap::coordinator::{
    Backend, EngineService, Job, NativeBackend, OpKind, PjrtBackend, ShardConfig,
    ShardedService, VectorEngine,
};
use mvap::diagram::StateDiagram;
use mvap::energy::{delay_cycles, DelayScheme, OpShape};
use mvap::exp;
use mvap::func::full_add;
use mvap::lutgen::{generate_blocked, generate_non_blocked};
use mvap::mvl::{Radix, Word};
use mvap::util::Rng;
use std::path::PathBuf;

fn random_words(rng: &mut Rng, rows: usize, p: usize, radix: Radix) -> Vec<Word> {
    (0..rows)
        .map(|_| Word::from_digits(rng.number(p, radix.n()), radix))
        .collect()
}

fn main() {
    let mut filters: Vec<String> = Vec::new();
    let mut json_path: Option<String> = None;
    let mut quick = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--quick" => {
                quick = true;
                harness::set_quick(true);
            }
            "--json" => {
                json_path = Some(args.next().expect("--json requires a path argument"));
            }
            a if a.starts_with('-') => {} // cargo's --bench etc.
            a => filters.push(a.to_string()),
        }
    }
    let run =
        |name: &str| filters.is_empty() || filters.iter().any(|f| name.contains(f));
    let mut results = Vec::new();
    println!("mvap benchmarks (filters: {:?})\n", filters);

    // ---- hot paths -------------------------------------------------------
    if run("hot/lutgen_non_blocked") {
        let table = full_add(Radix::TERNARY);
        results.push(bench("hot/lutgen_non_blocked_tfa", None, || {
            let d = StateDiagram::build(table.clone()).unwrap();
            black_box(generate_non_blocked(&d));
        }));
    }
    if run("hot/lutgen_blocked") {
        let table = full_add(Radix::TERNARY);
        results.push(bench("hot/lutgen_blocked_tfa", None, || {
            let d = StateDiagram::build(table.clone()).unwrap();
            black_box(generate_blocked(&d));
        }));
    }
    if run("hot/native_add") {
        let radix = Radix::TERNARY;
        let (rows, p) = (1024usize, 20usize);
        let mut rng = Rng::new(1);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let lut = adder_lut(radix, ExecMode::Blocked);
        results.push(bench(
            "hot/native_add_20t_1024rows_faithful",
            Some((rows * p) as u64),
            || {
                let (array, layout) = load_operands(radix, &a, &b, None);
                let mut ap = Ap::new(array);
                black_box(add_vectors(&mut ap, &layout, &lut, ExecMode::Blocked));
            },
        ));
        results.push(bench(
            "hot/native_add_20t_1024rows_fast",
            Some((rows * p) as u64),
            || {
                let (array, layout) = load_operands(radix, &a, &b, None);
                let mut ap = Ap::new(array);
                ap.apply_lut_multi_fast(&lut, &layout.positions(), ExecMode::Blocked);
                black_box(mvap::ap::extract_operand(ap.storage(), &layout));
            },
        ));
        results.push(bench(
            "hot/native_add_20t_1024rows_bitsliced",
            Some((rows * p) as u64),
            || {
                let (storage, layout) = mvap::ap::load_operands_storage(
                    StorageKind::BitSliced,
                    radix,
                    &a,
                    &b,
                    None,
                );
                let mut ap = Ap::with_storage(storage);
                ap.apply_lut_multi(&lut, &layout.positions(), ExecMode::Blocked);
                black_box(mvap::ap::extract_operand(ap.storage(), &layout));
            },
        ));
    }
    if run("hot/native_compare") {
        // pure compare throughput: one pass over a wide array
        let radix = Radix::TERNARY;
        let rows = 4096usize;
        let mut rng = Rng::new(2);
        let mut data = vec![0u8; rows * 41];
        rng.fill_digits(&mut data, 3);
        let array = mvap::cam::CamArray::from_data(radix, rows, 41, data);
        results.push(bench("hot/native_compare_4096rows", Some(rows as u64), || {
            black_box(array.compare(&[3, 23, 40], &[1, 2, 0]));
        }));
    }
    if run("hot/compare_storage") {
        // scalar vs bit-sliced compare throughput across array heights:
        // the tentpole claim (≥5x at ≥16k rows) is measured here.
        let radix = Radix::TERNARY;
        for &rows in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(12);
            let cols = 41;
            let mut data = vec![0u8; rows * cols];
            rng.fill_digits(&mut data, 3);
            let scalar = CamArray::from_data(radix, rows, cols, data.clone());
            let sliced = BitSlicedArray::from_data(radix, rows, cols, &data);
            results.push(bench(
                &format!("hot/compare_storage_scalar_{rows}rows"),
                Some(rows as u64),
                || {
                    black_box(scalar.compare(&[3, 23, 40], &[1, 2, 0]));
                },
            ));
            results.push(bench(
                &format!("hot/compare_storage_bitsliced_{rows}rows"),
                Some(rows as u64),
                || {
                    black_box(sliced.compare(&[3, 23, 40], &[1, 2, 0]));
                },
            ));
        }
    }
    if run("hot/write_storage") {
        // tagged masked write throughput, half the rows tagged
        let radix = Radix::TERNARY;
        let rows = 16 * 1024usize;
        let mut rng = Rng::new(13);
        let cols = 41;
        let mut data = vec![0u8; rows * cols];
        rng.fill_digits(&mut data, 3);
        let tags: Vec<bool> = (0..rows).map(|r| r % 2 == 0).collect();
        let mut scalar = CamArray::from_data(radix, rows, cols, data.clone());
        let mut sliced = BitSlicedArray::from_data(radix, rows, cols, &data);
        results.push(bench(
            "hot/write_storage_scalar_16384rows",
            Some(rows as u64),
            || {
                black_box(scalar.write(&tags, &[5, 17], &[2, 0]));
            },
        ));
        results.push(bench(
            "hot/write_storage_bitsliced_16384rows",
            Some(rows as u64),
            || {
                black_box(sliced.write(&tags, &[5, 17], &[2, 0]));
            },
        ));
    }
    if run("hot/fast_path") {
        // The state-bucketing fast path (the coordinator's tile executor)
        // across array heights, on both storages, plus the row-at-a-time
        // reference on bit-sliced storage — `fast_path_bitsliced` vs
        // `fast_path_rowwise_bitsliced` measures the plane-native win
        // (the PR-3 tentpole claim: ≥ 5x at 256k rows).
        let radix = Radix::TERNARY;
        let p = 8usize;
        let mode = ExecMode::Blocked;
        let lut = adder_lut(radix, mode);
        let kernel = LutKernel::compile(&lut, mode);
        for &rows in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(14);
            let a = random_words(&mut rng, rows, p, radix);
            let b = random_words(&mut rng, rows, p, radix);
            // Each iteration re-applies the LUT to the evolving array
            // in place (full_add is total, states stay in-radix), so the
            // timed region contains only fast-path work — no per-iteration
            // storage clone to dilute the plane-native vs row-wise ratio.
            for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
                let tag = match kind {
                    StorageKind::Scalar => "scalar",
                    StorageKind::BitSliced => "bitsliced",
                };
                let (storage, layout) =
                    mvap::ap::load_operands_storage(kind, radix, &a, &b, None);
                let positions = layout.positions();
                let mut ap = Ap::with_storage(storage);
                results.push(bench(
                    &format!("hot/fast_path_{tag}_{rows}rows"),
                    Some((rows * p) as u64),
                    || {
                        ap.apply_lut_multi_fast_kernel(&lut, &positions, mode, &kernel);
                        black_box(ap.stats().rows_written);
                    },
                ));
            }
            // the pre-kernel row-scalar fast path on bit-sliced storage
            let (storage, layout) =
                mvap::ap::load_operands_storage(StorageKind::BitSliced, radix, &a, &b, None);
            let positions = layout.positions();
            let mut ap = Ap::with_storage(storage);
            results.push(bench(
                &format!("hot/fast_path_rowwise_bitsliced_{rows}rows"),
                Some((rows * p) as u64),
                || {
                    ap.apply_lut_multi_fast_rowwise(&lut, &positions, mode);
                    black_box(ap.stats().rows_written);
                },
            ));
        }
    }
    if run("hot/parallel_apply") {
        // Data-parallel word-block execution of the bit-sliced fast path
        // (the PR-8 tentpole): the same evolving-array kernel application
        // as hot/fast_path_bitsliced, at 1/2/4/8 scoped threads plus the
        // plain sequential constructor as the baseline of record. `seq`
        // and `1t` run the identical code path (a 1-thread Parallelism
        // never partitions), so their delta bounds the knob's overhead;
        // `ci.sh` gates 4t >= 2x seq at 256k rows via tools/perf_gate.py.
        let radix = Radix::TERNARY;
        let p = 8usize;
        let mode = ExecMode::Blocked;
        let lut = adder_lut(radix, mode);
        let kernel = LutKernel::compile(&lut, mode);
        for &rows in &[16 * 1024usize, 256 * 1024, 1024 * 1024] {
            let mut rng = Rng::new(18);
            let a = random_words(&mut rng, rows, p, radix);
            let b = random_words(&mut rng, rows, p, radix);
            let variants: [(&str, Option<usize>); 5] = [
                ("seq", None),
                ("1t", Some(1)),
                ("2t", Some(2)),
                ("4t", Some(4)),
                ("8t", Some(8)),
            ];
            for (tag, threads) in variants {
                let (storage, layout) = mvap::ap::load_operands_storage(
                    StorageKind::BitSliced,
                    radix,
                    &a,
                    &b,
                    None,
                );
                let positions = layout.positions();
                let mut ap = Ap::with_storage(storage);
                if let Some(t) = threads {
                    ap = ap.with_parallelism(Parallelism::new(t));
                }
                results.push(bench(
                    &format!("hot/parallel_apply_{tag}_{rows}rows"),
                    Some((rows * p) as u64),
                    || {
                        ap.apply_lut_multi_fast_kernel(&lut, &positions, mode, &kernel);
                        black_box(ap.stats().rows_written);
                    },
                ));
            }
        }
    }
    if run("hot/trace") {
        // Telemetry overhead (the PR-10 zero-cost contract): the same
        // 256k-row bit-sliced execute with tracing disabled, attached but
        // disarmed (the not-sampled request path — one branch per span
        // site), and attached + armed (every span recorded into the
        // per-thread ring). `ci.sh` gates disarmed <= 1.02x off and
        // armed <= 1.10x off via tools/perf_gate.py.
        use mvap::telemetry::{SpanRecorder, Tracer};
        let radix = Radix::TERNARY;
        let (rows, p) = (256 * 1024usize, 8usize);
        let mut rng = Rng::new(21);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let job = Job::new(1, OpKind::Add, radix, true, a, b);
        let variants: [(&str, bool, bool); 3] =
            [("off", false, false), ("unsampled", true, false), ("sampled", true, true)];
        for (tag, attach, armed) in variants {
            let recorder = SpanRecorder::new(1);
            let mut eng =
                VectorEngine::new(Box::new(NativeBackend::new(StorageKind::BitSliced)));
            if attach {
                eng.set_tracer(Tracer::attach(&recorder, 1, 0));
                eng.tracer_mut().set_armed(armed);
            }
            results.push(bench(
                &format!("hot/trace_{tag}_{rows}rows"),
                Some((rows * p) as u64),
                || {
                    black_box(eng.execute(&job).unwrap());
                },
            ));
        }
    }
    if run("hot/arena") {
        // Per-call scratch hoisting: both variants clone the storage and
        // build a fresh Ap each iteration (identical fixed cost), but
        // `reuse` recycles the ApArena across iterations the way
        // NativeBackend does, so the delta is exactly the per-call
        // allocation of write-enable + classification scratch.
        let radix = Radix::TERNARY;
        let (rows, p) = (16 * 1024usize, 8usize);
        let mode = ExecMode::Blocked;
        let lut = adder_lut(radix, mode);
        let kernel = LutKernel::compile(&lut, mode);
        let mut rng = Rng::new(19);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let (storage, layout) =
            mvap::ap::load_operands_storage(StorageKind::BitSliced, radix, &a, &b, None);
        let positions = layout.positions();
        results.push(bench(
            &format!("hot/arena_fresh_{rows}rows"),
            Some((rows * p) as u64),
            || {
                let mut ap = Ap::with_storage(storage.clone());
                ap.apply_lut_multi_fast_kernel(&lut, &positions, mode, &kernel);
                black_box(ap.stats().rows_written);
            },
        ));
        let mut arena = ApArena::default();
        results.push(bench(
            &format!("hot/arena_reuse_{rows}rows"),
            Some((rows * p) as u64),
            || {
                let mut ap =
                    Ap::with_storage_arena(storage.clone(), std::mem::take(&mut arena));
                ap.apply_lut_multi_fast_kernel(&lut, &positions, mode, &kernel);
                black_box(ap.stats().rows_written);
                arena = ap.into_arena();
            },
        ));
    }
    if run("hot/kernel_cache") {
        // kernel compilation (cold) vs signature-keyed lookup (warm)
        let lut = adder_lut(Radix::TERNARY, ExecMode::Blocked);
        results.push(bench("hot/kernel_cache_cold", None, || {
            let cache = KernelCache::new();
            black_box(cache.get_or_compile(&lut, ExecMode::Blocked).0.num_states());
        }));
        let cache = KernelCache::new();
        cache.get_or_compile(&lut, ExecMode::Blocked);
        results.push(bench("hot/kernel_cache_warm", None, || {
            black_box(cache.get_or_compile(&lut, ExecMode::Blocked).0.num_states());
        }));
    }
    if run("hot/pjrt_add") {
        let dir = PathBuf::from("artifacts");
        if dir.join("manifest.txt").exists() {
            let radix = Radix::TERNARY;
            let (rows, p) = (1024usize, 20usize);
            let mut rng = Rng::new(3);
            let a = random_words(&mut rng, rows, p, radix);
            let b = random_words(&mut rng, rows, p, radix);
            let backend = PjrtBackend::new(&dir).expect("pjrt backend");
            let mut eng = VectorEngine::new(Box::new(backend));
            // prime the compile cache outside the timed region
            let job = Job::new(0, OpKind::Add, radix, true, a.clone(), b.clone());
            eng.execute(&job).unwrap();
            let mut id = 1u64;
            results.push(bench(
                "hot/pjrt_add_20t_1024rows",
                Some((rows * p) as u64),
                || {
                    let job = Job::new(id, OpKind::Add, radix, true, a.clone(), b.clone());
                    id += 1;
                    black_box(eng.execute(&job).unwrap());
                },
            ));
        } else {
            eprintln!("hot/pjrt_add skipped: run `make artifacts`");
        }
    }
    if run("hot/service_throughput") {
        let radix = Radix::TERNARY;
        let (rows, p, jobs) = (256usize, 20usize, 8usize);
        let mut rng = Rng::new(4);
        let a = random_words(&mut rng, rows, p, radix);
        let b = random_words(&mut rng, rows, p, radix);
        let svc = EngineService::start(4, 16, || {
            Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
        })
        .unwrap();
        results.push(bench(
            "hot/service_4workers_8jobs",
            Some((jobs * rows) as u64),
            || {
                let rxs: Vec<_> = (0..jobs as u64)
                    .map(|id| {
                        svc.submit(Job::new(id, OpKind::Add, radix, true, a.clone(), b.clone()))
                    })
                    .collect();
                for rx in rxs {
                    black_box(rx.recv().unwrap().unwrap());
                }
            },
        ));
        svc.shutdown();
    }
    if run("hot/coalesce") {
        // solo vs coalesced dispatch of a burst of small same-signature
        // jobs, at 1k/16k/256k total rows, on both storage backends: the
        // tentpole claim is that coalescing fills the row-parallel tiles
        // (watch the fill-rate lines) and raises throughput.
        let radix = Radix::TERNARY;
        let (p, job_rows) = (8usize, 32usize);
        for &total in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(41);
            let jobs: Vec<Job> = (0..(total / job_rows) as u64)
                .map(|id| {
                    let a = random_words(&mut rng, job_rows, p, radix);
                    let b = random_words(&mut rng, job_rows, p, radix);
                    Job::new(id, OpKind::Add, radix, true, a, b)
                })
                .collect();
            for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
                let tag = match kind {
                    StorageKind::Scalar => "scalar",
                    StorageKind::BitSliced => "bitsliced",
                };
                let mut solo = VectorEngine::new(Box::new(NativeBackend::new(kind)));
                results.push(bench(
                    &format!("hot/coalesce_solo_{tag}_{total}rows"),
                    Some(total as u64),
                    || {
                        for job in &jobs {
                            black_box(solo.execute(job).unwrap());
                        }
                    },
                ));
                let mut co = VectorEngine::new(Box::new(NativeBackend::new(kind)));
                results.push(bench(
                    &format!("hot/coalesce_batch_{tag}_{total}rows"),
                    Some(total as u64),
                    || {
                        black_box(co.execute_coalesced(&jobs).unwrap());
                    },
                ));
                println!(
                    "    fill rate ({tag}, {total} rows): solo {:.1}% -> coalesced {:.1}%",
                    100.0 * solo.metrics().fill_rate(),
                    100.0 * co.metrics().fill_rate()
                );
            }
        }
    }
    if run("hot/reduce") {
        // In-engine segmented tree reduction (OpKind::Reduce): one job
        // folds `rows` 8-trit operands down to one value in ⌈log₂ rows⌉
        // rounds, with plane-native row movement between rounds on the
        // bit-sliced backend. The bench of record for the PR-4 tentpole:
        // compare scalar vs bit-sliced at 1k/16k/256k rows (the old
        // host-paired path paid a job round-trip per round on top).
        let radix = Radix::TERNARY;
        let p = 8usize;
        for &rows in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(15);
            let values = random_words(&mut rng, rows, p, radix);
            for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
                let tag = match kind {
                    StorageKind::Scalar => "scalar",
                    StorageKind::BitSliced => "bitsliced",
                };
                let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
                let job = Job::reduce(1, radix, true, values.clone(), vec![]);
                results.push(bench(
                    &format!("hot/reduce_{tag}_{rows}rows"),
                    Some(rows as u64),
                    || {
                        black_box(eng.execute(&job).unwrap());
                    },
                ));
            }
        }
    }
    if run("hot/search") {
        // In-engine content-addressable search (the PR-9 tentpole): one
        // exact-match job over `rows` stored 8-trit words, scalar vs
        // bit-sliced at 1k/16k/256k rows. Exact match is a single compare
        // pass per plane, so this measures raw tag-readout throughput.
        let radix = Radix::TERNARY;
        let p = 8usize;
        for &rows in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(17);
            let values = random_words(&mut rng, rows, p, radix);
            let key = values[rows / 2].clone();
            for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
                let tag = match kind {
                    StorageKind::Scalar => "scalar",
                    StorageKind::BitSliced => "bitsliced",
                };
                let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
                let job = Job::search(1, radix, values.clone(), key.clone(), false, vec![]);
                results.push(bench(
                    &format!("hot/search_{tag}_{rows}rows"),
                    Some(rows as u64),
                    || {
                        black_box(eng.execute(&job).unwrap());
                    },
                ));
            }
        }
    }
    if run("hot/topk") {
        // Digit-serial top-k elimination (most-significant plane first,
        // early exit once the candidate pool drains): k = 16 largest of
        // `rows` stored words on the bit-sliced backend — the schedule is
        // data-dependent, so this is the bench of record for the
        // elimination path's host-side bookkeeping.
        let radix = Radix::TERNARY;
        let p = 8usize;
        for &rows in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(20);
            let values = random_words(&mut rng, rows, p, radix);
            let mut eng =
                VectorEngine::new(Box::new(NativeBackend::new(StorageKind::BitSliced)));
            let job = Job::topk(1, radix, values, 16, true, vec![]);
            results.push(bench(
                &format!("hot/topk_bitsliced_{rows}rows"),
                Some(rows as u64),
                || {
                    black_box(eng.execute(&job).unwrap());
                },
            ));
        }
    }
    if run("hot/program") {
        // Compiled dataflow programs (the PR-5 tentpole): the whole op
        // DAG executes as ONE engine invocation with CAM-resident
        // intermediates. `program_dot` = fused mac+reduce over N rows;
        // `program_fir` = 4 taps of mac + a 2-wave add tree (7 steps, 6
        // resident reuses — the workload that previously paid 7 job
        // round-trips). Scalar vs bit-sliced at 1k/16k/256k rows.
        use mvap::program::{builtin, BoundProgram};
        use std::sync::Arc;
        let radix = Radix::TERNARY;
        let p = 8usize;
        for &rows in &[1024usize, 16 * 1024, 256 * 1024] {
            let mut rng = Rng::new(16);
            let dot_plan = Arc::new(builtin::dot(radix, p).plan());
            let fir_plan = Arc::new(builtin::fir(radix, p, 4).plan());
            let dot_inputs: Vec<(&str, Vec<Word>)> = vec![
                ("a", random_words(&mut rng, rows, p, radix)),
                ("b", random_words(&mut rng, rows, p, radix)),
            ];
            let fir_names = ["x0", "x1", "x2", "x3", "h0", "h1", "h2", "h3"];
            let fir_inputs: Vec<(&str, Vec<Word>)> = fir_names
                .iter()
                .map(|n| (*n, random_words(&mut rng, rows, p, radix)))
                .collect();
            for kind in [StorageKind::Scalar, StorageKind::BitSliced] {
                let tag = match kind {
                    StorageKind::Scalar => "scalar",
                    StorageKind::BitSliced => "bitsliced",
                };
                let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
                let bound = BoundProgram::bind(&dot_plan, dot_inputs.clone(), true).unwrap();
                results.push(bench(
                    &format!("hot/program_dot_{tag}_{rows}rows"),
                    Some(rows as u64),
                    || {
                        black_box(eng.execute_program(&bound).unwrap());
                    },
                ));
                let mut eng = VectorEngine::new(Box::new(NativeBackend::new(kind)));
                let bound = BoundProgram::bind(&fir_plan, fir_inputs.clone(), true).unwrap();
                results.push(bench(
                    &format!("hot/program_fir_{tag}_{rows}rows"),
                    Some(rows as u64),
                    || {
                        black_box(eng.execute_program(&bound).unwrap());
                    },
                ));
            }
        }
    }
    if run("hot/sharded_service") {
        // end-to-end sharded dispatch with cross-submission coalescing
        let radix = Radix::TERNARY;
        let (p, job_rows, jobs_n) = (8usize, 32usize, 64usize);
        let mut rng = Rng::new(42);
        let jobs: Vec<Job> = (0..jobs_n as u64)
            .map(|id| {
                let a = random_words(&mut rng, job_rows, p, radix);
                let b = random_words(&mut rng, job_rows, p, radix);
                Job::new(id, OpKind::Add, radix, true, a, b)
            })
            .collect();
        let cfg = ShardConfig {
            shards: 4,
            queue_depth: 128,
            flush_after: std::time::Duration::from_micros(500),
            ..ShardConfig::default()
        };
        let svc = ShardedService::start(cfg, || {
            Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
        })
        .unwrap();
        results.push(bench(
            "hot/sharded_4x_64jobs_32rows",
            Some((jobs_n * job_rows) as u64),
            || {
                let rxs: Vec<_> = jobs
                    .iter()
                    .map(|j| svc.submit(j.clone()).expect("service closed"))
                    .collect();
                for rx in rxs {
                    black_box(rx.recv().unwrap().unwrap());
                }
            },
        ));
        let (agg, _) = svc.shutdown();
        println!("    sharded metrics: {}", agg.summary());
    }
    if run("hot/serving_frontdoor") {
        // one closed burst through the serving front door: admission
        // accounting + completion callbacks + per-class histograms on top
        // of the sharded dispatch path (the PR-7 tentpole overhead check
        // against hot/sharded_4x_64jobs_32rows).
        use mvap::serving::{FrontConfig, FrontDoor};
        let radix = Radix::TERNARY;
        let (p, job_rows, jobs_n) = (8usize, 32usize, 64usize);
        let mut rng = Rng::new(43);
        let jobs: Vec<Job> = (0..jobs_n as u64)
            .map(|id| {
                let a = random_words(&mut rng, job_rows, p, radix);
                let b = random_words(&mut rng, job_rows, p, radix);
                Job::new(id, OpKind::Add, radix, true, a, b)
            })
            .collect();
        let front_cfg = FrontConfig {
            max_in_flight: 256,
            shard: ShardConfig {
                shards: 4,
                queue_depth: 128,
                flush_after: std::time::Duration::from_micros(500),
                ..ShardConfig::default()
            },
        };
        let front = FrontDoor::start(front_cfg, || {
            Ok(Box::new(NativeBackend::default()) as Box<dyn Backend>)
        })
        .unwrap();
        results.push(bench(
            "hot/serving_frontdoor_4x_64jobs_32rows",
            Some((jobs_n * job_rows) as u64),
            || {
                let rxs: Vec<_> = jobs
                    .iter()
                    .map(|j| front.submit(j.clone()).expect("front door closed"))
                    .collect();
                for rx in rxs {
                    black_box(rx.recv().unwrap().unwrap());
                }
            },
        ));
        let (stats, engine, _) = front.shutdown();
        println!(
            "    front door: admitted={} completed={} shed={} | {}",
            stats.admitted,
            stats.completed,
            stats.shed,
            engine.summary()
        );
    }
    if run("hot/serving_histogram") {
        // the latency histogram itself: record throughput (the per-request
        // cost every shard worker pays) and p50/p95/p99 extraction.
        use mvap::serving::LatencyHistogram;
        let mut rng = Rng::new(44);
        let samples: Vec<u64> = (0..65_536).map(|_| 500 + rng.below(5_000_000)).collect();
        results.push(bench(
            "hot/serving_histogram_record_65536",
            Some(samples.len() as u64),
            || {
                let mut h = LatencyHistogram::default();
                for &ns in &samples {
                    h.record_ns(ns);
                }
                black_box(h.count());
            },
        ));
        let mut h = LatencyHistogram::default();
        for &ns in &samples {
            h.record_ns(ns);
        }
        results.push(bench("hot/serving_histogram_quantiles", None, || {
            black_box((h.quantile_ns(0.50), h.quantile_ns(0.95), h.quantile_ns(0.99)));
        }));
    }
    if run("hot/matchline_transient") {
        let sim = MatchlineSim { tech: CellTech::ternary_default(), masked_cells: 3 };
        results.push(bench("hot/matchline_transient_400steps", None, || {
            black_box(sim.evaluate(MatchClass(1)));
        }));
    }

    // ---- per-table / per-figure regeneration (render only, no stdout) ----
    if run("exp/table6") {
        results.push(bench("exp/table6", None, || {
            black_box(exp::tables::table6().0.render());
        }));
    }
    if run("exp/table7") {
        results.push(bench("exp/table7", None, || {
            black_box(exp::tables::table7().0.render());
        }));
    }
    if run("exp/table9") {
        results.push(bench("exp/table9_grplvl_trace", None, || {
            black_box(exp::tables::table9());
        }));
    }
    if run("exp/table10") {
        results.push(bench("exp/table10", None, || {
            black_box(exp::tables::table10().0.render());
        }));
    }
    if run("exp/fig9") {
        results.push(bench("exp/fig9", None, || {
            black_box(exp::fig9::run(DelayScheme::Traditional).tap_b);
        }));
    }
    if run("exp/fig6") || run("exp/fig7") {
        results.push(bench("exp/fig6+fig7_sweep", None, || {
            black_box(exp::circuit_dse::sweep());
        }));
    }
    if run("exp/table11") {
        results.push(bench("exp/table11_1000rows", Some(6 * 1000), || {
            black_box(exp::table11::run(1000, 1));
        }));
    }
    if run("exp/fig8") {
        results.push(bench("exp/fig8_1000rows", None, || {
            black_box(exp::fig8::run(1000, 1));
        }));
    }
    if run("model/delay") {
        let lut = adder_lut(Radix::TERNARY, ExecMode::Blocked);
        results.push(bench("model/delay_cycles", None, || {
            black_box(delay_cycles(OpShape::of(&lut, 20), DelayScheme::Traditional));
        }));
    }

    println!("\n==== summary ====");
    for r in &results {
        r.print();
    }

    if let Some(path) = json_path {
        let body: Vec<String> = results.iter().map(|r| format!("    {}", r.json())).collect();
        let doc = format!(
            "{{\n  \"suite\": \"mvap-bench\",\n  \"mode\": \"{}\",\n  \"results\": [\n{}\n  ]\n}}\n",
            if quick { "quick" } else { "full" },
            body.join(",\n")
        );
        std::fs::write(&path, doc).expect("write bench json");
        println!("\nwrote {path} ({} results)", results.len());
    }
}
