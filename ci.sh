#!/usr/bin/env bash
# Local CI gate for the mvap repo — documented in README.md.
#
#   ./ci.sh            run everything
#   ./ci.sh --fast     skip the release-test, clippy, doc and fmt stages
#
# Stages:
#   1. cargo build --release        (tier-1, part 1)
#   2. cargo test -q                (tier-1, part 2: unit + integration + doctests)
#   3. fixed-seed reproduction      (MVAP_PROP_SEED pins every property
#                                    sweep of the reduce, program, parallel
#                                    and search differential suites to one
#                                    replayable case — proves the replay
#                                    knob stays wired; any failing sweep
#                                    prints the same knob + seed. The
#                                    parallel suite includes the
#                                    thread-count-invariance property:
#                                    values/stats/energy/delay identical
#                                    across threads 1..8; the search suite
#                                    proves scalar ≡ bit-sliced ≡ host
#                                    reference for Search/Min/Max/TopK
#                                    values, match sets, stats, energy and
#                                    delay, coalesced ≡ solo included)
#   4. mvap modelcheck              (exhaustive model check of the shard
#                                    coordinator machine: every interleaving
#                                    of the bounded scenarios, no-loss /
#                                    no-duplication / conservation /
#                                    eventual-flush; FAILS LOUDLY on any
#                                    violation or zero explored states, and
#                                    regenerates docs/shard_machine.dot)
#   5. mvap serve (smoke)           (closed + open loop through the serving
#                                    front door: bounded admission, latency
#                                    histograms, zero panics across the
#                                    shutdown drain; records the latency
#                                    curves to BENCH_7.json at the repo root
#                                    and FAILS LOUDLY if it holds zero
#                                    results)
#   6. trace smoke                  (`mvap trace` replays the canned
#                                    coalesce + steal workload and the
#                                    resulting Chrome JSON must pass
#                                    tools/trace_check.py with complete
#                                    admit->reply flow chains, a stolen
#                                    reply, a >= 2-job flush, and span
#                                    energy reconciling with the metrics
#                                    snapshots to 1e-9; a traced
#                                    single-config `mvap serve` run is
#                                    then checked the same way)
#   7. cargo test --release -q      (the coalescing/bit-sliced fast paths,
#                                    exercised with optimizations on)
#   8. cargo bench --no-run         (benches must keep compiling)
#   9. cargo bench -- --quick       (hot-path benches, 3 iterations each,
#                                    recorded to BENCH_3/4/5/8/9/10.json at
#                                    the repo root — the perf trajectory
#                                    artifacts, each filtered to its PR's
#                                    benches of record (BENCH_9: the
#                                    in-engine search + topk path;
#                                    BENCH_10: the telemetry overhead
#                                    trio); FAILS LOUDLY if any
#                                    BENCH_*.json holds zero results, as
#                                    happened to BENCH_3.json.
#                                    BENCH_8.json then goes through
#                                    tools/perf_gate.py: 4-thread kernel
#                                    application at 256k rows must be
#                                    >= 2x the 1-thread p50 (skipped
#                                    loudly on < 4-CPU machines), and
#                                    1-thread must stay within 10% of the
#                                    sequential path; BENCH_10.json must
#                                    show a disarmed tracer <= 1.02x and
#                                    an armed tracer <= 1.10x of the
#                                    tracing-disabled execute at 256k
#                                    rows; the gate also distinguishes a
#                                    missing trajectory file from an
#                                    unpopulated one)
#  10. cargo clippy --all-targets   (warnings as errors; skipped with a note
#                                    if clippy is absent)
#  11. cargo doc --no-deps          (warnings as errors; the crate also denies
#                                    rustdoc::broken_intra_doc_links)
#  12. cargo fmt --check            (skipped with a note if rustfmt is absent)
set -euo pipefail
cd "$(dirname "$0")/rust"

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> fixed-seed reproduction (MVAP_PROP_SEED=0x5eedc0de, reduce + program + parallel + search differential suites)"
MVAP_PROP_SEED=0x5eedc0de cargo test -q --test reduce_differential --test program_differential \
    --test parallel_differential --test search_differential

echo "==> mvap modelcheck (exhaustive shard-coordinator verification)"
cargo run --release --quiet -- modelcheck --dot ../docs/shard_machine.dot

echo "==> mvap serve smoke (closed + open loop, 1- and 4-thread tiles, recording BENCH_7.json)"
cargo run --release --quiet -- serve --clients 8 --rps 2000 --duration 0.5 \
    --shards 2,4 --flush-us 500,2000 --threads 1,4 --req-rows 8 --digits 6 \
    --json ../BENCH_7.json
if ! grep -q '"name":' ../BENCH_7.json; then
    echo "ERROR: serve smoke recorded zero latency curves in BENCH_7.json" >&2
    exit 1
fi

echo "==> mvap trace smoke (canned coalesce + steal workload -> TRACE_smoke.json)"
cargo run --release --quiet -- trace --out ../TRACE_smoke.json
python3 ../tools/trace_check.py ../TRACE_smoke.json \
    --require-complete --require-steal --require-coalesce

echo "==> traced serve smoke (single config, every request sampled -> TRACE_serve.json)"
cargo run --release --quiet -- serve --clients 4 --duration 0.4 \
    --shards 2 --flush-us 500 --threads 1 --req-rows 8 --digits 6 \
    --trace ../TRACE_serve.json --trace-sample 1
python3 ../tools/trace_check.py ../TRACE_serve.json --require-complete --allow-drops

if [[ "$fast" == "0" ]]; then
    echo "==> cargo test --release -q"
    cargo test --release -q

    echo "==> cargo bench --no-run (compile gate)"
    cargo bench --no-run

    echo "==> cargo bench -- --quick (recording BENCH_3/4/5/8/9/10.json)"
    cargo bench --bench bench_main -- --quick --json ../BENCH_3.json \
        hot/fast_path hot/kernel_cache
    cargo bench --bench bench_main -- --quick --json ../BENCH_4.json hot/reduce
    cargo bench --bench bench_main -- --quick --json ../BENCH_5.json hot/
    cargo bench --bench bench_main -- --quick --json ../BENCH_8.json \
        hot/parallel_apply hot/arena hot/fast_path hot/kernel_cache hot/reduce
    cargo bench --bench bench_main -- --quick --json ../BENCH_9.json \
        hot/search hot/topk
    cargo bench --bench bench_main -- --quick --json ../BENCH_10.json hot/trace
    for trajectory in ../BENCH_*.json; do
        if ! grep -q '"name":' "$trajectory"; then
            echo "ERROR: quick-bench stage recorded zero results in ${trajectory#../}" >&2
            exit 1
        fi
    done

    echo "==> perf-regression gate (tools/perf_gate.py over BENCH_8 + BENCH_10)"
    python3 ../tools/perf_gate.py ../BENCH_8.json ../BENCH_10.json ../BENCH_3.json \
        ../BENCH_4.json ../BENCH_5.json ../BENCH_7.json ../BENCH_9.json

    if cargo clippy --version >/dev/null 2>&1; then
        echo "==> cargo clippy --all-targets (warnings as errors)"
        cargo clippy --all-targets -- -D warnings
    else
        echo "==> cargo clippy skipped (clippy not installed)"
    fi

    echo "==> cargo doc --no-deps (warnings as errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

    if cargo fmt --version >/dev/null 2>&1; then
        echo "==> cargo fmt --check"
        cargo fmt --check
    else
        echo "==> cargo fmt --check skipped (rustfmt not installed)"
    fi
fi

echo "CI gate passed."
