"""Exact Python port of the data-parallel word-block execution layer.

Mirrors ``rust/src/cam/parallel.rs`` (the ``word_cuts`` partitioning
rule) and the block-level semantics of
``BitSlicedArray::apply_states_parallel`` in
``rust/src/cam/bitsliced.rs``: per-block classification over contiguous
64-row word ranges, an all-blocks don't-care rendezvous (any block
aborts the whole application with nothing written), per-block partial
bucket counts, and the deterministic ascending-block-order reduction
whose integer sums equal the sequential whole-range popcounts exactly.

The model operates on a plain ``rows x cols`` digit matrix (``None`` =
don't-care) rather than packed ``u64`` planes: the packing is a layout
detail; what the port validates is the *partitioning and reduction
algebra* — that splitting rows into word blocks and summing per-block
partials is observably identical to the sequential pass, for any cut
vector ``word_cuts`` can produce.
"""

WORD_ROWS = 64  # rows per plane word, fixed by the u64 packing
DEFAULT_MIN_BLOCK_WORDS = 64


def word_cuts(threads, words, min_block_words=DEFAULT_MIN_BLOCK_WORDS):
    """Port of ``Parallelism::word_cuts``: cumulative block end offsets
    (last == ``words``), or ``None`` when the application must run
    sequentially. Blocks are as even as possible; the first
    ``words % blocks`` blocks get one extra word. Depends only on
    ``(threads, min_block_words, words)`` — never on the data."""
    min_words = max(min_block_words, 1)
    blocks = min(threads, words // min_words)
    if blocks < 2:
        return None
    base, extra = divmod(words, blocks)
    cuts, end = [], 0
    for b in range(blocks):
        end += base + (1 if b < extra else 0)
        cuts.append(end)
    assert cuts[-1] == words
    return cuts


def state_of(row_digits, radix):
    """State id of one row over the compared columns (most-significant
    column first, like the Rust state decode), or ``None`` if any digit
    is a don't-care."""
    sid = 0
    for d in row_digits:
        if d is None:
            return None
        sid = sid * radix + d
    return sid


def classify_rows(matrix, cols, radix, row_range):
    """Classify ``row_range`` of the matrix: returns ``(ok, states)``
    where ``states[i]`` is the state id of row ``row_range[i]``. ``ok``
    is False (states unspecified) if any row held a don't-care — the
    block-level abort signal."""
    states = []
    for r in row_range:
        sid = state_of([matrix[r][c] for c in cols], radix)
        if sid is None:
            return False, states
        states.append(sid)
    return True, states


def segment_of(row, bounds):
    """Index of the first segment whose end bound exceeds ``row``."""
    for i, b in enumerate(bounds):
        if row < b:
            return i
    raise ValueError(f"row {row} beyond the last bound {bounds[-1]}")


def apply_states_sequential(matrix, cols, radix, plan, bounds):
    """The sequential oracle: classify every row, abort on any
    don't-care (matrix unchanged), else count per-(segment, state) and
    rewrite the compared columns from ``plan[state]``. Returns
    ``(ok, counts)`` with ``counts`` flattened ``[segment][state]``."""
    rows = len(matrix)
    num_states = radix ** len(cols)
    ok, states = classify_rows(matrix, cols, radix, range(rows))
    if not ok:
        return False, None
    counts = [0] * (len(bounds) * num_states)
    for r, sid in enumerate(states):
        counts[segment_of(r, bounds) * num_states + sid] += 1
        for c, d in zip(cols, plan[sid]):
            matrix[r][c] = d
    return True, counts


def apply_states_parallel(matrix, cols, radix, plan, bounds, cuts):
    """The word-block execution model. Phase 1: every block classifies
    its own word range into private state lists and an abort flag.
    Barrier. Phase 2: if any block aborted, the whole application
    returns ``(False, None)`` with the matrix untouched; otherwise each
    block counts its partial ``[segment][state]`` populations and
    commits its merge, and the partials reduce in ascending block order.
    Every observable must equal ``apply_states_sequential``."""
    rows = len(matrix)
    num_states = radix ** len(cols)
    nsegs = len(bounds)

    block_rows, block_states, all_ok = [], [], True
    for b, end in enumerate(cuts):
        start = 0 if b == 0 else cuts[b - 1]
        rng = range(start * WORD_ROWS, min(end * WORD_ROWS, rows))
        ok, states = classify_rows(matrix, cols, radix, rng)
        block_rows.append(rng)
        block_states.append(states)
        all_ok = all_ok and ok

    # barrier: the don't-care rendezvous
    if not all_ok:
        return False, None

    partials = []
    for rng, states in zip(block_rows, block_states):
        counts = [0] * (nsegs * num_states)
        for r, sid in zip(rng, states):
            counts[segment_of(r, bounds) * num_states + sid] += 1
            for c, d in zip(cols, plan[sid]):
                matrix[r][c] = d
        partials.append(counts)

    # deterministic reduction, ascending block order
    counts = [0] * (nsegs * num_states)
    for partial in partials:
        for i, c in enumerate(partial):
            counts[i] += c
    return True, counts


def copy_rows_sequential(matrix, src_col, src_row, dst_col, dst_row, count):
    """Row-range column copy with memmove semantics (extract the source
    digits first, then write — overlap-safe), the sequential oracle for
    the plane-split decomposition."""
    moved = [matrix[src_row + i][src_col] for i in range(count)]
    for i, d in enumerate(moved):
        matrix[dst_row + i][dst_col] = d


def copy_rows_plane_split(matrix, radix, src_col, src_row, dst_col, dst_row, count):
    """Port of ``BitSlicedArray::copy_rows_parallel``: decompose the two
    columns into ``planes`` digit bit-planes plus the present plane,
    run the extract/merge move on every plane *independently* (each
    plane task sees only its own bits, as the scoped tasks do), then
    recompose digits. Must equal ``copy_rows_sequential`` bit for bit —
    including don't-care rows, which travel as present=0."""
    planes = max(1, (radix - 1).bit_length())
    rows = len(matrix)

    def plane_bits(col, p):
        out = []
        for r in range(rows):
            d = matrix[r][col]
            out.append(0 if d is None else (d >> p) & 1)
        return out

    def present_bits(col):
        return [0 if matrix[r][col] is None else 1 for r in range(rows)]

    # each task: extract the source bit range, then merge into the dest
    new_planes = []
    for p in range(planes):
        bits = plane_bits(dst_col, p)
        moved = plane_bits(src_col, p)[src_row : src_row + count]
        bits[dst_row : dst_row + count] = moved
        new_planes.append(bits)
    present = present_bits(dst_col)
    moved = present_bits(src_col)[src_row : src_row + count]
    present[dst_row : dst_row + count] = moved

    for r in range(dst_row, dst_row + count):
        if present[r] == 0:
            matrix[r][dst_col] = None
        else:
            matrix[r][dst_col] = sum(new_planes[p][r] << p for p in range(planes))
