"""Layer-1 Pallas kernel: one digit-wise LUT application over the CAM rows.

The LUT is a *compile-time constant* (baked into the kernel, as the pass
program is the AP's microcode); rows are the data-parallel axis, tiled by
``BlockSpec`` into VMEM-sized row blocks — the TPU adaptation of the
paper's word-parallel matchline array (see DESIGN.md §Hardware-Adaptation).

The kernel computes, per row block:
  * the blocked compare/write semantics (frozen state per write block,
    D-FF write-enable accumulation, one write per block);
  * the per-pass mismatch-class histogram (fm/1mm/2mm/3mm — the compare
    energy inputs of §VI-A);
  * the per-block changed-digit count (set/reset events, §VI-B).

Stats are accumulated across row blocks with the init-on-first-program
pattern, so the grid can tile arbitrarily many rows.

``interpret=True`` is mandatory on CPU: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT client cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..luts import Lut

# Row-block size: 3 int32 columns × 256 rows ≈ 3 KiB per operand block in
# VMEM — far under the ~16 MiB budget; chosen so stats reductions stay in
# registers. The caller pads rows to a multiple of this.
ROW_BLOCK = 256


def _static_lut(lut: Lut):
    """Freeze the LUT into hashable static structure:
    blocks = ((first_pass_idx, write_start, written, ((pass_idx, key), ...)), ...)."""
    blocks = []
    idx = {id(p): i for i, p in enumerate(lut.passes)}
    for block in lut.blocks():
        start, written = lut.write_of(block[0])
        passes = tuple((idx[id(p)], lut.decode(p.input)) for p in block)
        blocks.append((idx[id(block[0])], start, tuple(written), passes))
    return tuple(blocks)


def _lut_kernel(state_ref, out_ref, hist_ref, sets_ref, *, blocks, arity, num_passes):
    """Pallas kernel body. state_ref/out_ref: [BR, arity] int32;
    hist_ref: [num_passes, arity+1] int32; sets_ref: [num_passes] int32."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        sets_ref[...] = jnp.zeros_like(sets_ref)

    state = state_ref[...]
    class_ids = jnp.arange(arity + 1, dtype=jnp.int32)

    for first_idx, wstart, written, passes in blocks:
        frozen = state
        enable = jnp.zeros((frozen.shape[0],), dtype=jnp.bool_)
        for pass_idx, key in passes:
            mism = jnp.zeros((frozen.shape[0],), dtype=jnp.int32)
            for c in range(arity):
                mism += (frozen[:, c] != key[c]).astype(jnp.int32)
            # mismatch-class histogram for this pass
            contrib = (mism[:, None] == class_ids[None, :]).astype(jnp.int32).sum(axis=0)
            hist_ref[pass_idx, :] += contrib
            enable |= mism == 0
        # block write: all passes share `written` over columns [wstart, arity)
        changed = jnp.zeros((), dtype=jnp.int32)
        new_cols = []
        for c in range(arity):
            if c < wstart:
                new_cols.append(state[:, c])
            else:
                val = jnp.int32(written[c - wstart])
                changed += ((state[:, c] != val) & enable).astype(jnp.int32).sum()
                new_cols.append(jnp.where(enable, val, state[:, c]))
        sets_ref[first_idx] += changed
        state = jnp.stack(new_cols, axis=1)

    out_ref[...] = state


def apply_lut(state: jax.Array, lut: Lut) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Apply `lut` to `state` [R, arity] (int32, R a multiple of ROW_BLOCK).

    Returns (new_state [R, arity], hist [P, arity+1], sets [P]) — the same
    triple as `ref.apply_lut_ref`.
    """
    rows, arity = state.shape
    assert arity == lut.arity
    assert rows % ROW_BLOCK == 0, f"rows {rows} not a multiple of {ROW_BLOCK}"
    num_passes = len(lut.passes)
    blocks = _static_lut(lut)
    kernel = functools.partial(
        _lut_kernel, blocks=blocks, arity=arity, num_passes=num_passes
    )
    grid = (rows // ROW_BLOCK,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_BLOCK, arity), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROW_BLOCK, arity), lambda i: (i, 0)),
            pl.BlockSpec((num_passes, arity + 1), lambda i: (0, 0)),
            pl.BlockSpec((num_passes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, arity), jnp.int32),
            jax.ShapeDtypeStruct((num_passes, arity + 1), jnp.int32),
            jax.ShapeDtypeStruct((num_passes,), jnp.int32),
        ],
        interpret=True,
    )(state)
