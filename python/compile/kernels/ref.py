"""Pure-numpy oracle for the AP pass engine — the correctness reference the
Pallas kernel (and therefore every AOT artifact) is checked against.

Semantics mirror the paper exactly (§IV compare/write, §V blocked D-FF):
within a write block, compares see the block-start ("frozen") state; the
block's single write commits every row whose flip-flop was armed. The
non-blocked case is the degenerate one-pass-per-block instance.
"""

from __future__ import annotations

import numpy as np

from ..luts import Lut


def apply_lut_ref(state: np.ndarray, lut: Lut):
    """Apply one digit-wise LUT to ``state`` [R, arity] (int array).

    Returns ``(new_state, hist, sets)`` where ``hist[p, k]`` counts rows
    with exactly k mismatching cells during pass p's compare, and
    ``sets[p]`` counts changed-digit writes attributed to the first pass of
    each block (a changed digit = 1 set + 1 reset on the cell).
    """
    state = state.copy()
    rows, arity = state.shape
    assert arity == lut.arity
    num_passes = len(lut.passes)
    hist = np.zeros((num_passes, arity + 1), dtype=np.int64)
    sets = np.zeros(num_passes, dtype=np.int64)
    pass_index = {id(p): i for i, p in enumerate(lut.passes)}

    for block in lut.blocks():
        frozen = state.copy()
        enable = np.zeros(rows, dtype=bool)
        for p in block:
            i = pass_index[id(p)]
            key = np.array(lut.decode(p.input), dtype=state.dtype)
            mismatches = (frozen != key[None, :]).sum(axis=1)
            hist[i] = np.bincount(mismatches, minlength=arity + 1)
            enable |= mismatches == 0
        first = pass_index[id(block[0])]
        start, written = lut.write_of(block[0])
        written = np.array(written, dtype=state.dtype)
        changed = (state[:, start:] != written[None, :]) & enable[:, None]
        sets[first] += int(changed.sum())
        state[np.ix_(enable, range(start, arity))] = written[None, :]
    return state, hist, sets


def inplace_op_ref(array: np.ndarray, lut: Lut, p: int):
    """p-digit in-place op over ``array`` [R, 2p+1] (layout A|B|carry,
    LSB first). Returns (array', hist [p, P, arity+1], sets [p, P])."""
    array = array.copy()
    rows, cols = array.shape
    assert cols == 2 * p + 1
    hists, sets = [], []
    for d in range(p):
        cols_d = [d, p + d, 2 * p]
        state = array[:, cols_d]
        new_state, h, s = apply_lut_ref(state, lut)
        array[:, cols_d] = new_state
        hists.append(h)
        sets.append(s)
    return array, np.stack(hists), np.stack(sets)


def add_words_ref(a_digits: np.ndarray, b_digits: np.ndarray, radix: int):
    """Digit-wise reference addition: [R, p] little-endian operands →
    (sum [R, p], carry [R])."""
    rows, p = a_digits.shape
    out = np.zeros_like(a_digits)
    carry = np.zeros(rows, dtype=a_digits.dtype)
    for d in range(p):
        t = a_digits[:, d] + b_digits[:, d] + carry
        out[:, d] = t % radix
        carry = t // radix
    return out, carry
