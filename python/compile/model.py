"""Layer-2 JAX model: the p-digit in-place vector operation.

Composes the L1 kernel over digit positions with ``lax.scan`` (one trace of
the 21-pass kernel regardless of p — keeps the lowered HLO compact for
80-digit operands). The array layout is the paper's `A | B | carry` row of
N = 2p+1 cells, least-significant digit first.

This module is build-time only: ``aot.py`` lowers `inplace_op` to HLO text
which the Rust runtime executes via PJRT. Nothing here runs at request time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.ap_pass import apply_lut
from .luts import Lut


def inplace_op(array: jax.Array, lut: Lut, p: int):
    """Run the p-digit in-place op on `array` [R, 2p+1] int32.

    Returns (array', hist [p, P, arity+1], sets [p, P]).
    """
    rows, cols = array.shape
    assert cols == 2 * p + 1, f"expected {2 * p + 1} columns, got {cols}"
    carry_col = 2 * p

    def digit_step(arr, d):
        a_col = jax.lax.dynamic_slice(arr, (0, d), (rows, 1))
        b_col = jax.lax.dynamic_slice(arr, (0, p + d), (rows, 1))
        c_col = jax.lax.dynamic_slice(arr, (0, carry_col), (rows, 1))
        state = jnp.concatenate([a_col, b_col, c_col], axis=1)
        new_state, hist, sets = apply_lut(state, lut)
        arr = jax.lax.dynamic_update_slice(arr, new_state[:, 0:1], (0, d))
        arr = jax.lax.dynamic_update_slice(arr, new_state[:, 1:2], (0, p + d))
        arr = jax.lax.dynamic_update_slice(arr, new_state[:, 2:3], (0, carry_col))
        return arr, (hist, sets)

    array, (hists, sets) = jax.lax.scan(digit_step, array, jnp.arange(p, dtype=jnp.int32))
    return array, hists, sets


def make_engine(lut: Lut, rows: int, p: int):
    """A jit-able engine closure of static shape (rows × 2p+1) for `lut`."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def engine(array):
        return inplace_op(array, lut, p)

    return engine
