"""LUT generation (build-time Python mirror of ``rust/src/lutgen``).

The Rust crate is the reference implementation; this module re-derives the
same LUTs so the AOT pipeline is self-contained at build time. Semantic
equivalence with the Rust generator is enforced two ways:

* pytest goldens here assert the paper's invariants (21 passes / 9 blocks
  for the ternary full adder, the 101→020 cycle break, Table X block
  contents);
* the Rust integration tests cross-check the AOT-compiled engine against
  the native Rust simulator element-exactly on random workloads.

States are encoded big-endian base-n, matching the paper ('020' = 6).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable


@dataclass(frozen=True)
class Pass:
    """One LUT pass: compare ``input`` (state id), write the trailing
    ``write_dim`` digits of ``output`` into matching rows."""

    input: int
    output: int
    write_dim: int
    group: int


@dataclass
class Lut:
    name: str
    radix: int
    arity: int
    write_start: int
    passes: list[Pass] = field(default_factory=list)
    num_groups: int = 0

    def decode(self, sid: int) -> tuple[int, ...]:
        out = []
        for _ in range(self.arity):
            out.append(sid % self.radix)
            sid //= self.radix
        return tuple(reversed(out))

    def encode(self, digits) -> int:
        sid = 0
        for d in digits:
            sid = sid * self.radix + int(d)
        return sid

    def write_of(self, p: Pass) -> tuple[int, tuple[int, ...]]:
        """(first written column, written digits)."""
        out = self.decode(p.output)
        start = self.arity - p.write_dim
        return start, out[start:]

    def blocks(self) -> list[list[Pass]]:
        blocks: list[list[Pass]] = [[] for _ in range(self.num_groups)]
        for p in self.passes:
            blocks[p.group].append(p)
        return blocks


# ---------------------------------------------------------------------------
# truth tables


def full_add(radix: int) -> tuple[str, int, int, Callable]:
    """(name, arity, write_start, f) for the in-place full adder."""

    def f(s):
        total = s[0] + s[1] + s[2]
        return (s[0], total % radix, total // radix)

    return (f"full_add_r{radix}", 3, 1, f)


def full_sub(radix: int) -> tuple[str, int, int, Callable]:
    def f(s):
        d = s[0] - s[1] - s[2]
        borrow = 0
        while d < 0:
            d += radix
            borrow += 1
        return (s[0], d, borrow)

    return (f"full_sub_r{radix}", 3, 1, f)


def mac_digit(radix: int) -> tuple[str, int, int, Callable]:
    def f(s):
        v = s[0] * s[1] + s[2]
        return (s[0], v % radix, v // radix)

    return (f"mac_r{radix}", 3, 1, f)


# ---------------------------------------------------------------------------
# state diagram


class Diagram:
    """Functional graph of a truth table with cycle breaking — mirrors
    ``rust/src/diagram/graph.rs`` (same tie-breaks, same results)."""

    def __init__(self, name: str, radix: int, arity: int, write_start: int, f: Callable):
        self.name = name
        self.radix = radix
        self.arity = arity
        self.write_start = write_start
        self.count = radix**arity
        self.next: list[int] = []
        self.write_dim = [arity - write_start] * self.count
        for sid in range(self.count):
            digits = self._decode(sid)
            out = f(digits)
            assert tuple(out[:write_start]) == digits[:write_start]
            self.next.append(self._encode(out))
        self.no_action = [self.next[s] == s for s in range(self.count)]
        self.rewrites: list[tuple[int, int, int]] = []
        self._break_cycles()
        self.children: list[list[int]] = [[] for _ in range(self.count)]
        for s in range(self.count):
            if not self.no_action[s]:
                self.children[self.next[s]].append(s)
        self.level = [0] * self.count
        queue = [s for s in range(self.count) if self.no_action[s]]
        seen = set(queue)
        while queue:
            parent = queue.pop(0)
            for c in self.children[parent]:
                assert c not in seen, f"{self.name}: not a forest"
                seen.add(c)
                self.level[c] = self.level[parent] + 1
                queue.append(c)
        assert len(seen) == self.count, f"{self.name}: unbroken cycle"

    def _decode(self, sid: int) -> tuple[int, ...]:
        out = []
        for _ in range(self.arity):
            out.append(sid % self.radix)
            sid //= self.radix
        return tuple(reversed(out))

    def _encode(self, digits) -> int:
        sid = 0
        for d in digits:
            sid = sid * self.radix + int(d)
        return sid

    def _break_cycles(self) -> None:
        """Round-based (mirrors rust diagram::graph): redirect targets must
        currently reach a root, so chained cycle-merges are impossible; a
        function with no fixed point is rejected."""
        if not any(self.no_action):
            raise ValueError(
                f"{self.name}: no noAction state — not implementable in-place"
            )
        while True:
            reach = self._reach_root()
            cycles = self._find_cycles(reach)
            if not cycles:
                return
            progressed = False
            for cycle in cycles:
                pick = self._pick_redirect(cycle, reach)
                if pick is not None:
                    x, y2 = pick
                    self.rewrites.append((x, self.next[x], y2))
                    self.next[x] = y2
                    self.write_dim[x] = self.arity
                    progressed = True
            if not progressed:
                raise ValueError(
                    f"{self.name}: cycle {cycles[0]} admits no alternate "
                    "output reaching a root"
                )

    def _reach_root(self) -> list[bool]:
        color = [2 if self.no_action[s] else 0 for s in range(self.count)]
        for start in range(self.count):
            if color[start] != 0:
                continue
            path, cur = [], start
            while color[cur] == 0:
                color[cur] = 1
                path.append(cur)
                cur = self.next[cur]
            verdict = 2 if color[cur] == 2 else 3
            for s in path:
                color[s] = verdict
        return [c == 2 for c in color]

    def _find_cycles(self, reach: list[bool]) -> list[list[int]]:
        seen = [False] * self.count
        cycles = []
        for start in range(self.count):
            if reach[start] or seen[start]:
                continue
            path, on_path, cur = [], set(), start
            while not seen[cur] and cur not in on_path:
                on_path.add(cur)
                path.append(cur)
                cur = self.next[cur]
            if cur in on_path:
                cycles.append(path[path.index(cur):])
            for s in path:
                seen[s] = True
        return cycles

    def _pick_redirect(self, cycle: list[int], reach: list[bool]):
        kept = self.write_start
        best = None  # (score, -x, -y2) maximised
        for x in cycle:
            y = self.next[x]
            out = list(self._decode(y))
            for variant in range(self.radix**kept):
                digits = out[:]
                v = variant
                for i in reversed(range(kept)):
                    digits[i] = v % self.radix
                    v //= self.radix
                y2 = self._encode(digits)
                if y2 == y or y2 in cycle or not reach[y2]:
                    continue
                score = 3 if self.no_action[y2] else 2
                cand = (score, -x, -y2)
                if best is None or cand > best[0]:
                    best = (cand, x, y2)
        return None if best is None else (best[1], best[2])

    def out_val(self, sid: int, dim: int) -> int:
        digits = self._decode(sid)
        v = 0
        for d in digits[self.arity - dim:]:
            v = v * self.radix + d
        return v

    def group_key(self, sid: int) -> int:
        dim = self.write_dim[sid]
        offset = sum(self.radix**i for i in range(dim))
        return self.out_val(self.next[sid], dim) + offset


# ---------------------------------------------------------------------------
# generators


def _skeleton(d: Diagram) -> Lut:
    return Lut(name=d.name, radix=d.radix, arity=d.arity, write_start=d.write_start)


def generate_non_blocked(d: Diagram) -> Lut:
    """Algorithm 1: preorder DFS per tree, roots ascending."""
    lut = _skeleton(d)
    for root in (s for s in range(d.count) if d.no_action[s]):
        stack = list(reversed(d.children[root]))
        while stack:
            s = stack.pop()
            lut.passes.append(Pass(s, d.next[s], d.write_dim[s], len(lut.passes)))
            stack.extend(reversed(d.children[s]))
    lut.num_groups = len(lut.passes)
    return lut


def generate_blocked(d: Diagram) -> Lut:
    """Algorithms 2–4: grpLvl grouping (same sweep order as the Rust
    implementation: all eligible groups ascending per iteration)."""
    lut = _skeleton(d)
    level = list(d.level)
    grp = [d.group_key(s) if not d.no_action[s] else -1 for s in range(d.count)]
    next_group = max((g for g in grp if g >= 0), default=0) + 1
    blocks_emitted = 0

    def grp_lvl(l: int, g: int) -> int:
        return sum(1 for s in range(d.count) if grp[s] == g and level[s] == l)

    def top_total() -> int:
        return sum(1 for s in range(d.count) if grp[s] >= 0 and level[s] == 1)

    def update_lut(g_tgt: int) -> None:
        nonlocal blocks_emitted
        block = blocks_emitted
        blocks_emitted += 1
        members = [s for s in range(d.count) if grp[s] == g_tgt and level[s] == 1]
        assert members
        for j in members:
            lut.passes.append(Pass(j, d.next[j], d.write_dim[j], block))
            stack = list(d.children[j])
            while stack:
                v = stack.pop()
                level[v] -= 1
                stack.extend(d.children[v])
            grp[j] = -1

    while top_total() > 0:
        groups = sorted({g for g in grp if g >= 0})
        eligible = [
            g
            for g in groups
            if grp_lvl(1, g) > 0
            and all(grp_lvl(l, g) == 0 for l in range(2, max(level) + 1))
        ]
        if eligible:
            for g in eligible:
                update_lut(g)
        else:
            g_tgt = max(groups, key=lambda g: (grp_lvl(1, g), -g))
            for s in range(d.count):
                if grp[s] == g_tgt and level[s] > 1:
                    grp[s] = next_group
            next_group += 1
            update_lut(g_tgt)

    lut.num_groups = blocks_emitted
    return lut


def build_lut(fn: str, radix: int, blocked: bool) -> Lut:
    """Build a LUT by function name ('add' | 'sub' | 'mac')."""
    builders = {"add": full_add, "sub": full_sub, "mac": mac_digit}
    name, arity, ws, f = builders[fn](radix)
    d = Diagram(name, radix, arity, ws, f)
    return generate_blocked(d) if blocked else generate_non_blocked(d)
