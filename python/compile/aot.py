"""AOT driver: lower the L2 engine to HLO **text** for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
published ``xla`` crate's backend) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts land in ``artifacts/`` with a plain-text manifest (the offline
crate set has no serde, so the Rust side reads `key=value` lines):

    name=tap_add_nb_r1024_p20 file=tap_add_nb_r1024_p20.hlo.txt fn=add
    mode=non_blocked radix=3 rows=1024 digits=20 passes=21 groups=21

Run ``python -m compile.aot --out ../artifacts`` (the Makefile's
`make artifacts`).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .luts import build_lut
from .model import inplace_op


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# The artifact matrix: every (function, mode, radix, rows, digits) variant
# the Rust coordinator dispatches to. Rows are power-of-two tile sizes the
# batcher pads to; digits cover the paper's workload points used by the
# experiments and examples.
VARIANTS = [
    # fn,   mode,          radix, rows, digits
    ("add", "non_blocked", 3, 256, 20),
    ("add", "blocked", 3, 256, 20),
    ("add", "blocked", 3, 1024, 20),
    ("add", "blocked", 3, 256, 8),
    ("add", "non_blocked", 2, 256, 32),
    ("add", "blocked", 2, 256, 32),
    ("sub", "blocked", 3, 256, 20),
    ("mac", "blocked", 3, 256, 8),
]


def variant_name(fn: str, mode: str, radix: int, rows: int, digits: int) -> str:
    m = "nb" if mode == "non_blocked" else "b"
    return f"ap_{fn}_{m}_r{radix}_rows{rows}_p{digits}"


def lower_variant(fn: str, mode: str, radix: int, rows: int, digits: int) -> tuple[str, dict]:
    lut = build_lut(fn, radix, blocked=(mode == "blocked"))
    cols = 2 * digits + 1
    spec = jax.ShapeDtypeStruct((rows, cols), jnp.int32)
    lowered = jax.jit(lambda a: inplace_op(a, lut, digits)).lower(spec)
    text = to_hlo_text(lowered)
    meta = {
        "fn": fn,
        "mode": mode,
        "radix": radix,
        "rows": rows,
        "digits": digits,
        "passes": len(lut.passes),
        "groups": lut.num_groups,
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="comma list of variant names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest_lines = []
    only = set(args.only.split(",")) if args.only else None
    for fn, mode, radix, rows, digits in VARIANTS:
        name = variant_name(fn, mode, radix, rows, digits)
        if only and name not in only:
            continue
        text, meta = lower_variant(fn, mode, radix, rows, digits)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        fields = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest_lines.append(f"name={name} file={fname} {fields}")
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
