"""Python port of rust/src/modelcheck + coordinator/shard_machine.

This container authors the Rust side toolchain-less, so the machine
semantics are validated here: an exact, line-for-line port of

* ``BatchPolicy`` (logical-nanos flush policy, with ``rebase``),
* ``ShardCore.on_event`` (the pure worker transition → steps),
* ``ShardSystemMachine`` (bounded scenario model: queues, producers,
  deadline nondeterminism, stealing, shutdown),
* the exhaustive BFS explorer (safety invariants, deadlock detection,
  liveness via backward reachability, shortest traces).

The one deliberate divergence: the Rust model routes jobs through the
production ``JobSignature::shard`` SipHash, which is deterministic but
opaque. Here the routing is a parameter, and the validation sweeps
EVERY possible assignment of signatures to shards — the Rust behavior
is one point of that sweep, so properties proved for all routings hold
for it. Run ``python3 modelcheck_port.py`` for the full validation
sweep used to size the scenarios wired into ci.sh.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from itertools import product

FLUSH, ADMIT, RUN_PROGRAM, STEAL, EXIT = "Flush", "Admit", "RunProgram", "Steal", "Exit"

FLUSH_AFTER = 1_000  # model flush_after in nanos (scale is unobservable)


class BatchPolicy:
    """Mirror of coordinator::shard_machine::BatchPolicy."""

    __slots__ = ("max_jobs", "max_rows", "flush_after", "jobs", "rows", "sig", "deadline")

    def __init__(self, max_jobs, max_rows, flush_after=FLUSH_AFTER):
        self.max_jobs = max_jobs
        self.max_rows = max_rows
        self.flush_after = flush_after
        self.jobs = 0
        self.rows = 0
        self.sig = None
        self.deadline = None

    def key(self):
        return (self.jobs, self.rows, self.sig, self.deadline)

    def load(self, key):
        self.jobs, self.rows, self.sig, self.deadline = key
        return self

    def must_flush_before(self, sig):
        return self.sig is not None and self.sig != sig

    def admit(self, sig, rows, now):
        assert not self.must_flush_before(sig), "flush before admitting"
        if self.jobs == 0:
            self.sig = sig
            self.deadline = now + self.flush_after
        self.jobs += 1
        self.rows += rows
        return (
            self.jobs >= self.max_jobs
            or self.rows >= self.max_rows
            or (self.deadline is not None and now >= self.deadline)
        )

    def should_flush(self, now):
        return self.jobs > 0 and self.deadline is not None and now >= self.deadline

    def may_steal(self):
        return self.jobs == 0

    def flushed(self):
        self.jobs = 0
        self.rows = 0
        self.sig = None
        self.deadline = None

    def rebase(self):
        self.deadline = self.flush_after if self.jobs > 0 else None


class ShardCore:
    """Mirror of coordinator::shard_machine::ShardCore.on_event."""

    __slots__ = ("policy", "steal")

    def __init__(self, max_jobs, max_rows, steal):
        self.policy = BatchPolicy(max_jobs, max_rows)
        self.steal = steal

    def key(self):
        return self.policy.key()

    def on_event(self, event, now):
        kind = event[0]
        if kind == "job":
            _, sig, rows = event
            steps = []
            if self.policy.must_flush_before(sig):
                self.policy.flushed()
                steps.append(FLUSH)
            steps.append(ADMIT)
            if self.policy.admit(sig, rows, now):
                self.policy.flushed()
                steps.append(FLUSH)
            return steps
        if kind == "prog":
            self.policy.flushed()
            return [FLUSH, RUN_PROGRAM]
        if kind == "timeout":
            steps = []
            if self.policy.should_flush(now):
                self.policy.flushed()
                steps.append(FLUSH)
            if self.steal and self.policy.may_steal():
                steps.append(STEAL)
            return steps
        if kind == "closed":
            self.policy.flushed()
            return [FLUSH, EXIT]
        raise AssertionError(f"unknown event {event!r}")


@dataclass(frozen=True)
class Scenario:
    shards: int
    queue_depth: int
    max_batch_jobs: int
    max_batch_rows: int
    steal: bool
    # producers: tuple of tuples of items; item = ("job", sig, rows) | ("prog",)
    producers: tuple

    def items(self):
        out = []
        for plist in self.producers:
            out.extend(plist)
        return out

    def offsets(self):
        offs, at = [], 0
        for plist in self.producers:
            offs.append(at)
            at += len(plist)
        return offs


def mixed(shards, queue_depth, max_batch_jobs, steal, producers, jobs, programs, sigs,
          max_batch_rows=4):
    """Mirror of ShardScenario::mixed."""
    lists = [[] for _ in range(producers)]
    for j in range(jobs):
        lists[j % producers].append(("job", j % sigs, 1 + j % 3))
    for p in range(programs):
        lists[(jobs + p) % producers].append(("prog",))
    return Scenario(shards, queue_depth, max_batch_jobs, max_batch_rows, steal,
                    tuple(tuple(l) for l in lists))


class Violation(Exception):
    pass


class SystemMachine:
    """Mirror of ShardSystemMachine, with routing as a parameter.

    ``route`` maps a signature id to its home shard (the Rust model uses
    the production SipHash; sweeping every route covers it).
    State tuple layout:
      (produced, next_program, queues, pending, cores, expired, done,
       closed, exited)
    with queues/pending tuples of tuples of item ids, cores a tuple of
    policy keys.
    """

    def __init__(self, scenario, route):
        self.sc = scenario
        self.route = route
        self.items = scenario.items()
        self.offsets = scenario.offsets()
        assert len(self.items) <= 32

    def all_done(self):
        return (1 << len(self.items)) - 1

    def core(self, key):
        c = ShardCore(self.sc.max_batch_jobs, self.sc.max_batch_rows, self.sc.steal)
        c.policy.load(key)
        return c

    def home(self, item, next_program):
        if item[0] == "job":
            return self.route(item[1])
        return next_program % self.sc.shards

    def initial(self):
        n = self.sc.shards
        empty = tuple(() for _ in range(n))
        fresh = ShardCore(self.sc.max_batch_jobs, self.sc.max_batch_rows, self.sc.steal)
        return (
            tuple(0 for _ in self.sc.producers),  # produced
            0,                                    # next_program
            empty,                                # queues
            empty,                                # pending
            tuple(fresh.key() for _ in range(n)), # cores
            tuple(False for _ in range(n)),       # expired
            0,                                    # done
            False,                                # closed
            tuple(False for _ in range(n)),       # exited
        )

    def now(self, cores, expired, s):
        jobs = cores[s][0]
        return FLUSH_AFTER if (jobs > 0 and expired[s]) else 0

    def producers_done(self, st):
        return all(c == len(p) for c, p in zip(st[0], self.sc.producers))

    def timeout_effectful(self, st, s):
        produced, _np, queues, _pending, cores, expired, _done, _closed, _ex = st
        pending_jobs = cores[s][0]
        would_flush = pending_jobs > 0 and expired[s]
        would_steal = (
            self.sc.steal
            and pending_jobs == 0
            and any(i != s and len(queues[i]) > 0 for i in range(self.sc.shards))
        )
        return would_flush or would_steal

    def actions(self, st):
        produced, next_program, queues, pending, cores, expired, done, closed, exited = st
        out = []
        for p, plist in enumerate(self.sc.producers):
            cursor = produced[p]
            if closed or cursor >= len(plist):
                continue
            home = self.home(plist[cursor], next_program)
            if len(queues[home]) < self.sc.queue_depth:
                out.append(("submit", p))
        if not closed and self.producers_done(st):
            out.append(("close",))
        for s in range(self.sc.shards):
            if exited[s]:
                continue
            if len(queues[s]) > 0:
                out.append(("pop", s))
            if len(queues[s]) == 0 and self.timeout_effectful(st, s):
                out.append(("timeout", s))
            if cores[s][0] > 0 and not expired[s]:
                out.append(("deadline", s))
            if closed and len(queues[s]) == 0:
                out.append(("drain", s))
        return out

    # -- transition helpers (mutable mirror of run_steps) ---------------

    def _mark_done(self, mstate, item_id):
        if mstate["done"] & (1 << item_id):
            raise Violation(f"no-duplication violated: item {item_id} executed twice")
        mstate["done"] |= 1 << item_id

    def _do_flush(self, mstate, s):
        mstate["expired"][s] = False
        batch, mstate["pending"][s] = mstate["pending"][s], []
        for item_id in batch:
            self._mark_done(mstate, item_id)

    def _run_steps(self, mstate, s, steps, item_id):
        for step in steps:
            if step == FLUSH:
                self._do_flush(mstate, s)
            elif step == ADMIT:
                assert item_id is not None
                mstate["pending"][s].append(item_id)
                item_id = None
            elif step == RUN_PROGRAM:
                assert item_id is not None
                self._mark_done(mstate, item_id)
                item_id = None
            elif step == STEAL:
                for other in range(self.sc.shards):
                    if other == s or not mstate["queues"][other]:
                        continue
                    stolen = mstate["queues"][other].pop(0)
                    ev = self._event_of(stolen)
                    now = FLUSH_AFTER if (mstate["cores"][s].policy.jobs > 0
                                          and mstate["expired"][s]) else 0
                    nested = mstate["cores"][s].on_event(ev, now)
                    self._run_steps(mstate, s, nested, stolen)
                    break
            elif step == EXIT:
                mstate["exited"][s] = True
            else:
                raise AssertionError(step)

    def _event_of(self, item_id):
        item = self.items[item_id]
        if item[0] == "job":
            return ("job", item[1], item[2])
        return ("prog",)

    def _worker_event(self, mstate, s, event, item_id):
        now = FLUSH_AFTER if (mstate["cores"][s].policy.jobs > 0
                              and mstate["expired"][s]) else 0
        steps = mstate["cores"][s].on_event(event, now)
        self._run_steps(mstate, s, steps, item_id)
        mstate["cores"][s].policy.rebase()

    def transition(self, st, action):
        produced, next_program, queues, pending, cores, expired, done, closed, exited = st
        mstate = {
            "produced": list(produced),
            "next_program": next_program,
            "queues": [list(q) for q in queues],
            "pending": [list(p) for p in pending],
            "cores": [self.core(k) for k in cores],
            "expired": list(expired),
            "done": done,
            "closed": closed,
            "exited": list(exited),
        }
        kind = action[0]
        if kind == "submit":
            p = action[1]
            cursor = mstate["produced"][p]
            item = self.sc.producers[p][cursor]
            item_id = self.offsets[p] + cursor
            home = self.home(item, mstate["next_program"])
            mstate["queues"][home].append(item_id)
            mstate["produced"][p] += 1
            if item[0] == "prog":
                mstate["next_program"] += 1
        elif kind == "close":
            mstate["closed"] = True
        elif kind == "pop":
            s = action[1]
            item_id = mstate["queues"][s].pop(0)
            self._worker_event(mstate, s, self._event_of(item_id), item_id)
        elif kind == "timeout":
            self._worker_event(mstate, action[1], ("timeout",), None)
        elif kind == "deadline":
            mstate["expired"][action[1]] = True
        elif kind == "drain":
            self._worker_event(mstate, action[1], ("closed",), None)
        else:
            raise AssertionError(action)
        return (
            tuple(mstate["produced"]),
            mstate["next_program"],
            tuple(tuple(q) for q in mstate["queues"]),
            tuple(tuple(p) for p in mstate["pending"]),
            tuple(c.key() for c in mstate["cores"]),
            tuple(mstate["expired"]),
            mstate["done"],
            mstate["closed"],
            tuple(mstate["exited"]),
        )

    def invariant(self, st):
        produced, _np, queues, pending, cores, expired, done, closed, exited = st
        seen = [0] * len(self.items)
        for s, q in enumerate(queues):
            if len(q) > self.sc.queue_depth:
                raise Violation(f"queue {s} over depth")
            for item_id in q:
                seen[item_id] += 1
        for batch in pending:
            for item_id in batch:
                seen[item_id] += 1
        for p, plist in enumerate(self.sc.producers):
            for j in range(len(plist)):
                item_id = self.offsets[p] + j
                submitted = j < produced[p]
                places = seen[item_id] + (1 if done & (1 << item_id) else 0)
                if not submitted and places != 0:
                    raise Violation(f"item {item_id} present before submission")
                if submitted and places == 0:
                    raise Violation(f"item {item_id} lost (no-loss violated)")
                if submitted and places > 1:
                    raise Violation(f"item {item_id} in {places} places (no-duplication)")
        for s in range(self.sc.shards):
            jobs, rows_counted, sig, _deadline = cores[s]
            if jobs != len(pending[s]):
                raise Violation(f"shard {s}: policy jobs {jobs} != batch {len(pending[s])}")
            rows = 0
            for item_id in pending[s]:
                item = self.items[item_id]
                if item[0] != "job":
                    raise Violation(f"shard {s}: program {item_id} entered the batch")
                rows += item[2]
                if sig != item[1]:
                    raise Violation(f"shard {s}: batch mixes signatures")
            if rows_counted != rows:
                raise Violation(f"shard {s}: policy rows {rows_counted} != batch {rows}")
            if pending[s] and (
                len(pending[s]) >= self.sc.max_batch_jobs or rows >= self.sc.max_batch_rows
            ):
                raise Violation(f"shard {s}: batch at thresholds survived an event")
            if expired[s] and not pending[s]:
                raise Violation(f"shard {s}: expired without pending")
            if exited[s] and (queues[s] or pending[s]):
                raise Violation(f"shard {s}: exited with work left")
        if closed and not self.producers_done(st):
            raise Violation("closed before every producer finished")

    def is_goal(self, st):
        _p, _np, _q, _pend, _c, _e, done, closed, exited = st
        return closed and all(exited) and done == self.all_done()


@dataclass
class Report:
    states: int = 0
    transitions: int = 0
    depth: int = 0
    terminal: int = 0
    goals: int = 0


def explore(machine, max_states=5_000_000, check_deadlock=True, check_liveness=True):
    """Mirror of modelcheck::explore (BFS, dedup, invariants, liveness)."""
    init = machine.initial()
    machine.invariant(init)
    states = [init]
    index = {init: 0}
    depth = [0]
    edges = []
    rep = Report()
    rep.goals += 1 if machine.is_goal(init) else 0
    i = 0
    while i < len(states):
        st = states[i]
        acts = machine.actions(st)
        if not acts:
            rep.terminal += 1
            if check_deadlock and not machine.is_goal(st):
                raise Violation(f"deadlock at state {i} (depth {depth[i]})")
        for a in acts:
            nxt = machine.transition(st, a)
            rep.transitions += 1
            if nxt not in index:
                if len(states) >= max_states:
                    raise Violation(f"state limit {max_states}")
                index[nxt] = len(states)
                states.append(nxt)
                depth.append(depth[i] + 1)
                machine.invariant(nxt)
                if machine.is_goal(nxt):
                    rep.goals += 1
            edges.append((i, index[nxt]))
        i += 1
    if check_liveness:
        n = len(states)
        rev = [[] for _ in range(n)]
        for f, t in edges:
            rev[t].append(f)
        reach = [False] * n
        queue = deque(j for j in range(n) if machine.is_goal(states[j]))
        for j in queue:
            reach[j] = True
        while queue:
            j = queue.popleft()
            for p in rev[j]:
                if not reach[p]:
                    reach[p] = True
                    queue.append(p)
        bad = [j for j in range(n) if not reach[j]]
        if bad:
            raise Violation(f"liveness: {len(bad)} states cannot reach a goal (first {bad[0]})")
    rep.states = len(states)
    rep.depth = max(depth) if depth else 0
    return rep


def all_routes(sigs, shards):
    """Every assignment of signature ids 0..sigs-1 to shards."""
    for combo in product(range(shards), repeat=sigs):
        yield lambda s, c=combo: c[s]


def sweep(scenario, sigs, **kw):
    """Explore a scenario under every routing; returns per-route reports."""
    reports = []
    for route in all_routes(sigs, scenario.shards):
        reports.append(explore(SystemMachine(scenario, route), **kw))
    return reports


if __name__ == "__main__":
    import sys
    import time

    cases = [
        # (label, scenario, sig count)
        ("A 2sh d2 b2 steal 2prod 3j+1p 2sig", mixed(2, 2, 2, True, 2, 3, 1, 2), 2),
        ("B 3sh d2 b2 steal 2prod 3j+2p 3sig", mixed(3, 2, 2, True, 2, 3, 2, 3), 3),
        ("C 2sh d3 b3 nosteal 1prod 4j+1p 2sig", mixed(2, 3, 3, False, 1, 4, 1, 2), 2),
        ("D 2sh d2 b2 steal 1prod 1j+1p 1sig (DOT)", mixed(2, 2, 2, True, 1, 1, 1, 1), 1),
        ("E 2sh d2 b2 steal 2prod 4j+2p 2sig", mixed(2, 2, 2, True, 2, 4, 2, 2), 2),
    ]
    ok = True
    for label, sc, sigs in cases:
        t0 = time.time()
        try:
            reports = sweep(sc, sigs)
            lo = min(r.states for r in reports)
            hi = max(r.states for r in reports)
            tr = max(r.transitions for r in reports)
            dp = max(r.depth for r in reports)
            g = min(r.goals for r in reports)
            print(f"  {label}: states {lo}..{hi} over {len(reports)} routes, "
                  f"max transitions {tr}, max depth {dp}, min goals {g}, "
                  f"{time.time() - t0:.1f}s")
        except Violation as v:
            ok = False
            print(f"  {label}: VIOLATION {v}")
    sys.exit(0 if ok else 1)
