"""L2 correctness: the scanned multi-digit engine vs the numpy oracle and
vs plain integer arithmetic."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ap_pass import ROW_BLOCK
from compile.kernels.ref import add_words_ref, inplace_op_ref
from compile.luts import build_lut
from compile.model import make_engine


def build_array(rng, rows, p, radix):
    """Random A|B|carry array, carry cleared."""
    arr = rng.integers(0, radix, size=(rows, 2 * p + 1), dtype=np.int32)
    arr[:, 2 * p] = 0
    return arr


@pytest.mark.parametrize("mode", [False, True])
def test_engine_matches_ref(mode):
    p, rows, radix = 5, ROW_BLOCK, 3
    lut = build_lut("add", radix, blocked=mode)
    rng = np.random.default_rng(3)
    arr = build_array(rng, rows, p, radix)
    engine = make_engine(lut, rows, p)
    got_arr, got_hist, got_sets = engine(arr.copy())
    ref_arr, ref_hist, ref_sets = inplace_op_ref(arr, lut, p)
    np.testing.assert_array_equal(np.asarray(got_arr), ref_arr)
    np.testing.assert_array_equal(np.asarray(got_hist), ref_hist)
    np.testing.assert_array_equal(np.asarray(got_sets), ref_sets)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    p=st.integers(1, 12),
    radix=st.sampled_from([2, 3]),
    blocked=st.booleans(),
)
def test_engine_addition_is_correct(seed, p, radix, blocked):
    """B ← A + B: the engine's written digits equal base-radix addition."""
    rows = ROW_BLOCK
    lut = build_lut("add", radix, blocked=blocked)
    rng = np.random.default_rng(seed)
    arr = build_array(rng, rows, p, radix)
    a, b = arr[:, :p].copy(), arr[:, p : 2 * p].copy()
    engine = make_engine(lut, rows, p)
    out, _, _ = engine(arr)
    out = np.asarray(out)
    expect_sum, expect_carry = add_words_ref(a, b, radix)
    np.testing.assert_array_equal(out[:, p : 2 * p], expect_sum)
    np.testing.assert_array_equal(out[:, 2 * p], expect_carry)


def test_engine_sub_correct():
    p, rows, radix = 6, ROW_BLOCK, 3
    lut = build_lut("sub", radix, blocked=True)
    rng = np.random.default_rng(11)
    arr = build_array(rng, rows, p, radix)
    a, b = arr[:, :p].copy(), arr[:, p : 2 * p].copy()
    out, _, _ = make_engine(lut, rows, p)(arr)
    out = np.asarray(out)
    # digit-wise A - B with borrow ripple
    borrow = np.zeros(rows, dtype=np.int64)
    for d in range(p):
        t = a[:, d].astype(np.int64) - b[:, d] - borrow
        expect = np.mod(t, radix)
        borrow = np.where(t < 0, np.ceil(-t / radix).astype(np.int64), 0)
        np.testing.assert_array_equal(out[:, p + d], expect, err_msg=f"digit {d}")


def test_stats_digit_axis():
    """hist stacks one entry per digit position."""
    p, rows = 4, ROW_BLOCK
    lut = build_lut("add", 3, blocked=True)
    rng = np.random.default_rng(5)
    arr = build_array(rng, rows, p, 3)
    _, hist, sets = make_engine(lut, rows, p)(arr)
    assert np.asarray(hist).shape == (p, 21, 4)
    assert np.asarray(sets).shape == (p, 21)
    assert (np.asarray(hist).sum(axis=2) == rows).all()
