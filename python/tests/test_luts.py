"""Golden + property tests for the Python LUT generator (mirror of the Rust
reference — same invariants as rust/src/lutgen tests)."""

import itertools

import pytest

from compile.luts import Diagram, Lut, build_lut, full_add, full_sub, mac_digit


def replay(lut: Lut, initial: int) -> tuple[int, int]:
    """Deferred-semantics replay of one stored state; returns (final,
    applications)."""
    state = list(lut.decode(initial))
    apps = 0
    for block in lut.blocks():
        sid = lut.encode(state)
        hit = next((p for p in block if p.input == sid), None)
        if hit is not None:
            start, written = lut.write_of(hit)
            state[start:] = list(written)
            apps += 1
    return lut.encode(state), apps


@pytest.mark.parametrize("radix", [2, 3, 4, 5])
@pytest.mark.parametrize("fn", ["add", "sub", "mac"])
@pytest.mark.parametrize("blocked", [False, True])
def test_lut_soundness(radix, fn, blocked):
    """Replaying the LUT over every state yields the function's written
    digits with exactly one application for action states."""
    builders = {"add": full_add, "sub": full_sub, "mac": mac_digit}
    name, arity, ws, f = builders[fn](radix)
    lut = build_lut(fn, radix, blocked)
    for sid in range(radix**arity):
        digits = lut.decode(sid)
        expect = f(digits)
        final, apps = replay(lut, sid)
        got = lut.decode(final)
        assert got[ws:] == tuple(expect[ws:]), f"{name} state {digits}"
        is_noaction = tuple(expect) == digits
        assert apps == (0 if is_noaction else 1), f"{name} state {digits}"


def test_tfa_pass_and_group_counts():
    """Table VII: 21 passes; Table X: 9 write blocks."""
    nb = build_lut("add", 3, blocked=False)
    b = build_lut("add", 3, blocked=True)
    assert len(nb.passes) == 21 and nb.num_groups == 21
    assert len(b.passes) == 21 and b.num_groups == 9


def test_tfa_cycle_break_is_101_to_020():
    """§IV-B: input 101 is rewritten to output 020 with a 3-trit write."""
    lut = build_lut("add", 3, blocked=False)
    widened = [p for p in lut.passes if p.write_dim == 3]
    assert len(widened) == 1
    assert lut.decode(widened[0].input) == (1, 0, 1)
    assert lut.decode(widened[0].output) == (0, 2, 0)


def test_tfa_blocked_contents_match_table_x():
    """Block contents equal Table X (order among simultaneously-eligible
    blocks is arbitrary — compared as a set of sets)."""
    lut = build_lut("add", 3, blocked=True)
    ours = {
        frozenset("".join(map(str, lut.decode(p.input))) for p in block)
        for block in lut.blocks()
    }
    paper = {
        frozenset(b)
        for b in [
            {"101"},
            {"102", "111", "120", "210"},
            {"112", "121", "202", "220"},
            {"002", "011", "110", "200"},
            {"122", "212"},
            {"001", "100"},
            {"222"},
            {"012", "021"},
            {"022"},
        ]
    }
    assert ours == paper


def test_binary_adder_is_table_vi():
    """Radix-2 full adder: 4 action passes over {001, 011, 100, 110}."""
    lut = build_lut("add", 2, blocked=False)
    inputs = sorted("".join(map(str, lut.decode(p.input))) for p in lut.passes)
    assert inputs == ["001", "011", "100", "110"]


def test_parent_before_child_everywhere():
    for radix, fn in itertools.product([2, 3, 4], ["add", "sub", "mac"]):
        lut = build_lut(fn, radix, blocked=True)
        builders = {"add": full_add, "sub": full_sub, "mac": mac_digit}
        name, arity, ws, f = builders[fn](radix)
        d = Diagram(name, radix, arity, ws, f)
        pos = {p.input: i for i, p in enumerate(lut.passes)}
        for p in lut.passes:
            parent = d.next[p.input]
            if not d.no_action[parent]:
                assert pos[parent] < pos[p.input], f"{name}: {p.input}"


def test_blocks_share_write_action():
    for radix in [2, 3, 4]:
        lut = build_lut("add", radix, blocked=True)
        for block in lut.blocks():
            actions = {lut.write_of(p) for p in block}
            assert len(actions) == 1
