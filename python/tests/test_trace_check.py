"""Validation suite for tools/trace_check.py, the Chrome-trace checker.

Run directly: ``python3 python/tests/test_trace_check.py``.

The checker guards the CI trace smoke (`ci.sh` runs it over the `mvap
trace` and traced-serve outputs), so this suite proves both directions:
a well-formed trace passes every check, and each class of corruption —
unbalanced stacks, dangling flows, misplaced flow endpoints, energy
daylight, silent drops — is rejected with a loud error.
"""

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "tools"))

import trace_check  # noqa: E402
from trace_check import TraceError, check  # noqa: E402


def _ev(ph, ts, pid=100, tid=0, name=None, cat=None, eid=None, args=None, **extra):
    ev = {"ph": ph, "ts": ts, "pid": pid, "tid": tid}
    if name is not None:
        ev["name"] = name
    if cat is not None:
        ev["cat"] = cat
    if eid is not None:
        ev["id"] = eid
    if args is not None:
        ev["args"] = args
    ev.update(extra)
    return ev


def good_doc():
    """One request's full chain (admit -> flush/exec -> job -> reply with
    a flow arrow) plus one program span, with reconciling snapshots."""
    events = [
        _ev("M", 0, pid=0, tid=0, name="process_name", args={"name": "client edge"}),
        # client edge: admit span opening flow 0x1
        _ev("B", 10.0, pid=0, tid=1, name="admit", cat="mvap", args={"class": "batch"}),
        _ev("s", 12.0, pid=0, tid=1, name="req", cat="flow", eid="0x1"),
        _ev("E", 14.0, pid=0, tid=1),
        # shard 0: flush > exec, the async job span, reply finishing the flow
        _ev("B", 20.0, name="flush", cat="mvap",
            args={"jobs": 2, "rows": 128, "stolen": 0, "reason": "size"}),
        _ev("B", 21.0, name="exec", cat="mvap"),
        _ev("b", 21.5, name="job", cat="req", eid="0x1",
            args={"energyJ": 2.5e-9, "rows": 64}),
        _ev("E", 27.0),
        _ev("e", 27.5, name="job", cat="req", eid="0x1"),
        _ev("B", 28.0, name="reply", cat="mvap",
            args={"queueNs": 90, "latencyNs": 250, "stolen": True}),
        _ev("f", 28.2, name="req", cat="flow", eid="0x1", bp="e"),
        _ev("E", 28.5),
        _ev("E", 29.0),
        # a program span (sync, carries its own energy; steps would not)
        _ev("B", 30.0, name="program", cat="mvap",
            args={"req": "0x8000000000000002", "energyJ": 1.0e-9, "steps": 2}),
        _ev("B", 30.2, name="step", cat="mvap", args={"energyJ": 0.5e-9}),
        _ev("E", 30.6),
        _ev("E", 31.0),
    ]
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"sample": 1, "droppedSpans": 0},
        "metricsSnapshots": [
            {"scope": "aggregate", "label": "t", "modeledEnergyJ": 3.5e-9},
            # shard-scope snapshots must NOT be double-counted
            {"scope": "shard", "label": "s0", "modeledEnergyJ": 999.0},
        ],
    }


def run(doc, **kwargs):
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as fh:
        json.dump(doc, fh)
        path = fh.name
    try:
        check(path, **kwargs)
    finally:
        os.unlink(path)


def expect_fail(doc, fragment, **kwargs):
    try:
        run(doc, **kwargs)
    except TraceError as e:
        assert fragment in str(e), f"expected '{fragment}' in: {e}"
        return
    raise AssertionError(f"expected failure mentioning '{fragment}', but passed")


def test_good_trace_passes():
    run(good_doc())
    # ... including under every strictness flag it was built to satisfy
    run(good_doc(), require_complete=True, require_steal=True,
        require_coalesce=True)
    print("good trace ok")


def test_envelope_is_required():
    doc = good_doc()
    doc["traceEvents"] = []
    expect_fail(doc, "missing or empty")
    doc = good_doc()
    del doc["otherData"]["sample"]
    expect_fail(doc, "otherData")
    print("envelope checks ok")


def test_sync_stack_discipline():
    # an extra E with nothing open
    doc = good_doc()
    doc["traceEvents"].append(_ev("E", 40.0))
    expect_fail(doc, "no open span")
    # an unclosed B
    doc = good_doc()
    doc["traceEvents"].append(_ev("B", 41.0, name="exec", cat="mvap"))
    expect_fail(doc, "unclosed")
    # time running backwards within a lane
    doc = good_doc()
    doc["traceEvents"].extend([
        _ev("B", 50.0, name="exec", cat="mvap"),
        _ev("E", 49.0),
    ])
    expect_fail(doc, "regressed")
    print("sync stack checks ok")


def test_async_balance():
    doc = good_doc()
    doc["traceEvents"].append(
        _ev("b", 42.0, name="job", cat="req", eid="0x9", args={"energyJ": 0.0}))
    expect_fail(doc, "never closed")
    doc = good_doc()
    doc["traceEvents"].append(_ev("e", 43.0, name="job", cat="req", eid="0x9"))
    expect_fail(doc, "no open b")
    print("async balance checks ok")


def test_flow_chains():
    # a started flow that never finishes is always fatal
    doc = good_doc()
    doc["traceEvents"][1:1] = [
        _ev("B", 5.0, pid=0, tid=1, name="admit", cat="mvap"),
        _ev("s", 5.5, pid=0, tid=1, name="req", cat="flow", eid="0x7"),
        _ev("E", 6.0, pid=0, tid=1),
    ]
    expect_fail(doc, "never finished")
    # a finish without a start passes by default (edge-less `mvap run`
    # traces), but --require-complete rejects it
    doc = good_doc()
    doc["traceEvents"].extend([
        _ev("B", 44.0, name="reply", cat="mvap", args={"stolen": False}),
        _ev("f", 44.2, name="req", cat="flow", eid="0x7", bp="e"),
        _ev("E", 44.5),
    ])
    run(doc)
    expect_fail(doc, "never started", require_complete=True)
    # flow endpoints must sit inside the right span kinds
    doc = good_doc()
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "s":
            ev["ts"] = 15.0  # after the admit span closed
    expect_fail(doc, "not inside an admit")
    doc = good_doc()
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "f":
            ev["ts"] = 27.8  # between exec and reply
    expect_fail(doc, "not inside a reply")
    print("flow chain checks ok")


def test_energy_reconciliation():
    # daylight between span energy and the aggregate snapshot
    doc = good_doc()
    doc["metricsSnapshots"][0]["modeledEnergyJ"] = 4.0e-9
    expect_fail(doc, "reconcile")
    # sampling below 1/1 skips reconciliation (energy without spans)
    doc = good_doc()
    doc["metricsSnapshots"][0]["modeledEnergyJ"] = 4.0e-9
    doc["otherData"]["sample"] = 4
    run(doc)
    # no aggregate snapshots: skipped
    doc = good_doc()
    doc["metricsSnapshots"] = []
    run(doc)
    # step spans carry energyJ but must not be double-counted: the good
    # doc already contains one and reconciles without it
    assert trace_check.span_energy_j(good_doc()["traceEvents"]) == 3.5e-9
    print("energy reconciliation checks ok")


def test_dropped_spans():
    doc = good_doc()
    doc["otherData"]["droppedSpans"] = 3
    expect_fail(doc, "dropped")
    # --allow-drops tolerates them and skips the deep checks, so even a
    # dangling flow start goes unpunished (the span it finished in may
    # have been the one dropped)
    doc["traceEvents"][1:1] = [
        _ev("B", 5.0, pid=0, tid=1, name="admit", cat="mvap"),
        _ev("s", 5.5, pid=0, tid=1, name="req", cat="flow", eid="0x7"),
        _ev("E", 6.0, pid=0, tid=1),
    ]
    run(doc, allow_drops=True)
    print("dropped-span checks ok")


def test_requirements():
    doc = good_doc()
    for ev in doc["traceEvents"]:
        if ev.get("name") == "reply":
            ev["args"]["stolen"] = False
    run(doc)
    expect_fail(doc, "require-steal", require_steal=True)
    doc = good_doc()
    for ev in doc["traceEvents"]:
        if ev.get("name") == "flush":
            ev["args"]["jobs"] = 1
    expect_fail(doc, "require-coalesce", require_coalesce=True)
    print("requirement flag checks ok")


if __name__ == "__main__":
    test_good_trace_passes()
    test_envelope_is_required()
    test_sync_stack_discipline()
    test_async_balance()
    test_flow_chains()
    test_energy_reconciliation()
    test_dropped_spans()
    test_requirements()
    print("ALL TRACE CHECK TESTS PASSED")
