"""Validation suite for the data-parallel execution port (parallel_port.py).

Run directly: ``python3 python/tests/test_parallel_port.py`` or via
pytest. Four layers:

  1. structural properties of ``word_cuts`` — exact coverage, block
     evenness (sizes differ by at most one word), the sequential
     ``None`` conditions, and the ``min_block_words`` floor — over
     exhaustive small sweeps mirroring the Rust unit tests in
     ``rust/src/cam/parallel.rs``;
  2. the partial-stats reduction: block-partitioned classify + count +
     merge is observably identical to the sequential pass (counts,
     written matrix) for randomized radices 2-5, word-boundary and
     mid-word row counts, random segment bounds, and every cut vector;
  3. don't-care abort agreement: whenever any block sees a don't-care,
     both executions abort with the matrix untouched;
  4. the plane-split ``copy_rows`` decomposition equals the sequential
     memmove copy, don't-care rows included.
"""

import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from parallel_port import (  # noqa: E402
    WORD_ROWS,
    apply_states_parallel,
    apply_states_sequential,
    copy_rows_plane_split,
    copy_rows_sequential,
    word_cuts,
)

SEED = int(os.environ.get("MVAP_PROP_SEED", "0xd1ff"), 0)


def test_word_cuts_structure():
    # mirrors `cuts_are_even_exhaustive` in rust/src/cam/parallel.rs
    for threads in range(1, 10):
        for words in range(1, 41):
            cuts = word_cuts(threads, words, min_block_words=1)
            if cuts is None:
                assert min(threads, words) < 2, (threads, words)
                continue
            assert 2 <= len(cuts) <= min(threads, words)
            assert cuts[-1] == words
            sizes = [b - a for a, b in zip([0] + cuts, cuts)]
            assert max(sizes) - min(sizes) <= 1, (threads, words, cuts)
            assert min(sizes) >= 1


def test_word_cuts_sequential_conditions():
    # one thread never cuts, regardless of array size
    assert word_cuts(1, 1 << 20) is None
    # min_block_words floors the block count (Rust `min_block_words_floors_block_count`)
    assert word_cuts(8, 7, min_block_words=4) is None
    assert word_cuts(8, 11, min_block_words=4) == [6, 11]
    assert len(word_cuts(8, 64, min_block_words=4)) == 8
    # below 2 * default min_block_words the default config stays sequential
    assert word_cuts(8, 127) is None
    assert word_cuts(8, 128) is not None


def random_case(rng, dont_care_p):
    """One randomized kernel application: a digit matrix, compared
    columns, a random state->digits rewrite plan, and segment bounds."""
    radix = rng.randint(2, 5)
    k = rng.randint(1, 2)
    cols_total = k + rng.randint(0, 2)
    # bias rows onto word boundaries, like the Rust `boundary_rows`
    rows = rng.choice(
        [
            rng.randint(1, WORD_ROWS - 1),
            WORD_ROWS * rng.randint(1, 6),
            WORD_ROWS * rng.randint(1, 6) + rng.randint(1, 5),
        ]
    )
    matrix = [
        [
            None if rng.random() < dont_care_p else rng.randrange(radix)
            for _ in range(cols_total)
        ]
        for _ in range(rows)
    ]
    cols = rng.sample(range(cols_total), k)
    plan = [
        tuple(rng.randrange(radix) for _ in range(k)) for _ in range(radix**k)
    ]
    nsegs = rng.randint(1, 4)
    bounds = sorted(rng.randint(0, rows) for _ in range(nsegs - 1)) + [rows]
    return radix, rows, matrix, cols, plan, bounds


def every_cut_vector(rng, rows):
    """All distinct cut vectors the partitioning rule can produce for
    this row count, across thread counts 2/3/8 and block floors."""
    words = (rows + WORD_ROWS - 1) // WORD_ROWS
    seen, out = set(), []
    for threads in (2, 3, 8):
        for min_words in (1, 2):
            cuts = word_cuts(threads, words, min_block_words=min_words)
            if cuts and tuple(cuts) not in seen:
                seen.add(tuple(cuts))
                out.append(cuts)
    return out


def test_partial_stats_reduction_matches_sequential():
    rng = random.Random(SEED)
    checked = 0
    for _ in range(300):
        radix, rows, matrix, cols, plan, bounds = random_case(rng, dont_care_p=0.0)
        seq = [row[:] for row in matrix]
        ok_seq, counts_seq = apply_states_sequential(seq, cols, radix, plan, bounds)
        assert ok_seq  # no don't-cares in this sweep
        assert sum(counts_seq) == rows
        for cuts in every_cut_vector(rng, rows):
            par = [row[:] for row in matrix]
            ok_par, counts_par = apply_states_parallel(
                par, cols, radix, plan, bounds, cuts
            )
            assert ok_par
            assert counts_par == counts_seq, (radix, rows, cols, bounds, cuts)
            assert par == seq, (radix, rows, cols, cuts)
            checked += 1
    assert checked > 100  # the sweep must actually exercise multi-block cuts


def test_dont_care_abort_agreement():
    rng = random.Random(SEED ^ 0xABBA)
    aborted = 0
    for _ in range(300):
        radix, rows, matrix, cols, plan, bounds = random_case(rng, dont_care_p=0.05)
        seq = [row[:] for row in matrix]
        ok_seq, counts_seq = apply_states_sequential(seq, cols, radix, plan, bounds)
        for cuts in every_cut_vector(rng, rows):
            par = [row[:] for row in matrix]
            ok_par, counts_par = apply_states_parallel(
                par, cols, radix, plan, bounds, cuts
            )
            assert ok_par == ok_seq, (radix, rows, cols, cuts)
            if not ok_par:
                # abort leaves both matrices untouched
                assert par == matrix and seq == matrix
                aborted += 1
            else:
                assert counts_par == counts_seq
                assert par == seq
    assert aborted > 0  # the don't-care density must trigger some aborts


def test_copy_rows_plane_split_matches_sequential():
    rng = random.Random(SEED ^ 0xC0B4)
    for _ in range(300):
        radix = rng.randint(2, 5)
        rows = rng.choice([WORD_ROWS, WORD_ROWS + 1, 3 * WORD_ROWS, 200])
        cols = rng.randint(2, 4)
        matrix = [
            [
                None if rng.random() < 0.1 else rng.randrange(radix)
                for _ in range(cols)
            ]
            for _ in range(rows)
        ]
        count = rng.randint(0, rows)
        src_col, dst_col = rng.randrange(cols), rng.randrange(cols)
        src_row = rng.randint(0, rows - count)
        dst_row = rng.randint(0, rows - count)
        seq = [row[:] for row in matrix]
        copy_rows_sequential(seq, src_col, src_row, dst_col, dst_row, count)
        par = [row[:] for row in matrix]
        copy_rows_plane_split(par, radix, src_col, src_row, dst_col, dst_row, count)
        assert par == seq, (radix, rows, src_col, src_row, dst_col, dst_row, count)


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_") and callable(fn):
            fn()
            print(f"{name}: ok")
    print("parallel_port validation passed.")
