"""Validation suite for the content-addressable search port
(search_port.py). Run directly: ``python3 python/tests/test_search_port.py``
or via pytest. Three layers:

  1. schedule ≡ oracle: the engine compare schedules (exact, nearest,
     MS-first Min/Max elimination, repeated-extraction TopK) return the
     same hit sets as the pure host oracles, over randomized radices 2-5,
     don't-care stored digits, duplicates, and edge shapes (single row,
     all-equal, k = 0, k > rows);
  2. event accounting: pass counts follow the schedule structure (exact
     = 1, nearest = p, radix-2 extremes ≤ p via the implied last probe,
     early exit at one candidate = 0 passes), histograms sum to
     rows × passes, and search records no writes by construction;
  3. the golden pins: the deterministic radix-2..5 Min/Max fixture whose
     pass counts, histograms, and compare energies
     ``rust/tests/golden_values.rs`` asserts verbatim — derived HERE, so
     a drift in either language breaks one suite or the other.

Seed via MVAP_PROP_SEED for replay, like the Rust property tests.
"""

import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from search_port import (  # noqa: E402
    GOLDEN_DIGITS,
    GOLDEN_ROWS,
    Stats,
    golden_extreme_pin,
    golden_values,
    host_exact,
    host_extreme,
    host_nearest,
    host_topk,
    price_compare,
    search_exact,
    search_extreme,
    search_nearest,
    search_topk,
)

SEED = int(os.environ.get("MVAP_PROP_SEED", "0x5ea7c4"), 0)

# The numbers golden_search_elimination_pins (rust/tests/golden_values.rs)
# asserts: {radix: {largest: (passes, [full_matches, mismatches])}} over
# the shared (r * 37 + 11) % radix**4 fixture, 48 rows x 4 digits.
GOLDEN_PINS = {
    2: {False: (4, [96, 96]), True: (4, [96, 96])},
    3: {False: (3, [47, 97]), True: (4, [63, 129])},
    4: {False: (5, [61, 179]), True: (4, [49, 143])},
    5: {False: (5, [50, 190]), True: (6, [54, 234])},
}


def random_words(rng, rows, p, radix, wild_p=0.0):
    return [
        [None if rng.random() < wild_p else rng.randrange(radix)
         for _ in range(p)]
        for _ in range(rows)
    ]


def test_exact_schedule_matches_oracle():
    rng = random.Random(SEED)
    for _ in range(60):
        radix = rng.randrange(2, 6)
        p = rng.randrange(1, 6)
        rows = rng.randrange(1, 60)
        values = random_words(rng, rows, p, radix, wild_p=0.05)
        # half the probes are stored rows (guaranteed hits), half random
        key = (list(values[rng.randrange(rows)]) if rng.random() < 0.5
               else [rng.randrange(radix) for _ in range(p)])
        hits, stats = search_exact(values, key)
        assert hits == host_exact(values, key)
        assert stats.compare_cycles == 1, "exact match is one compare cycle"
        assert sum(stats.hist) == rows
        assert stats.hist[0] == len(hits)


def test_nearest_schedule_matches_oracle():
    rng = random.Random(SEED + 1)
    for _ in range(60):
        radix = rng.randrange(2, 6)
        p = rng.randrange(1, 6)
        rows = rng.randrange(1, 60)
        values = random_words(rng, rows, p, radix, wild_p=0.05)
        key = [rng.randrange(radix) for _ in range(p)]
        hits, dist, stats = search_nearest(values, key)
        want_rows, want_dist = host_nearest(values, key)
        assert hits == want_rows
        assert dist == want_dist
        assert stats.compare_cycles == p, "one compare cycle per digit"
        assert sum(stats.hist) == rows * p


def test_extreme_schedule_matches_oracle():
    rng = random.Random(SEED + 2)
    for _ in range(80):
        radix = rng.randrange(2, 6)
        p = rng.randrange(1, 7)
        rows = rng.randrange(1, 80)
        values = random_words(rng, rows, p, radix, wild_p=0.05)
        for largest in (False, True):
            hits, stats = search_extreme(values, radix, largest)
            assert hits == host_extreme(values, radix, largest)
            assert sorted(hits) == hits, "ties report ascending"
            # every pass compares the whole segment
            assert sum(stats.hist) == rows * stats.compare_cycles
            # the implied-last-value rule bounds the schedule
            assert stats.compare_cycles <= p * (radix - 1)


def test_topk_schedule_matches_oracle():
    rng = random.Random(SEED + 3)
    for _ in range(60):
        radix = rng.randrange(2, 6)
        p = rng.randrange(1, 6)
        rows = rng.randrange(1, 40)
        values = random_words(rng, rows, p, radix)
        k = rng.randrange(0, rows + 3)
        largest = rng.random() < 0.5
        hits, _ = search_topk(values, radix, k, largest)
        assert hits == host_topk(values, radix, k, largest)
        assert len(hits) == min(k, rows)


def test_edge_cases():
    # single row: a lone candidate needs no elimination passes
    hits, stats = search_extreme([[2, 1]], 3, False)
    assert hits == [0] and stats.compare_cycles == 0
    # all rows equal: every row ties
    values = [[1, 2, 0]] * 5
    hits, _ = search_extreme(values, 3, True)
    assert hits == [0, 1, 2, 3, 4]
    # k = 0 is free; k > rows returns the full ordering
    hits, stats = search_topk(values, 3, 0, True)
    assert hits == [] and stats.compare_cycles == 0
    # little-endian digits: [0,1] stores value 3, [1,0] stores value 1
    hits, _ = search_topk([[0, 1], [1, 0]], 3, 99, True)
    assert hits == [0, 1]
    # empty match set: a miss still costs the one compare cycle
    hits, stats = search_exact([[0, 0], [2, 2]], [1, 1])
    assert hits == [] and stats.compare_cycles == 1
    # a stored don't-care matches any key and acts as the scan-best value
    assert host_exact([[None, 1], [0, 1]], [2, 1]) == [0]
    assert host_extreme([[None, 0], [2, 0], [1, 1]], 3, False) == [0]
    assert host_extreme([[None, 2], [1, 2], [0, 0]], 3, True) == [0]


def test_binary_extreme_is_one_pass_per_digit():
    # radix 2: scan length 1 per digit (the classic bit-serial bound)
    rng = random.Random(SEED + 4)
    for _ in range(20):
        p = rng.randrange(1, 8)
        rows = rng.randrange(2, 40)
        values = random_words(rng, rows, p, 2)
        _, stats = search_extreme(values, 2, True)
        assert stats.compare_cycles <= p


def test_stats_merge_shape():
    s = Stats()
    s.record_compare([5, 1, 0, 2])
    s.record_compare([3, 0, 1])
    assert s.compare_cycles == 2
    assert s.hist == [8, 1, 1, 2]


def test_golden_pins():
    # the fixture itself is deterministic and in-radix
    for radix in (2, 3, 4, 5):
        values = golden_values(radix)
        assert len(values) == GOLDEN_ROWS
        assert all(len(w) == GOLDEN_DIGITS for w in values)
        assert all(0 <= d < radix for w in values for d in w)
        for largest in (False, True):
            passes, hist, energy = golden_extreme_pin(radix, largest)
            want_passes, want_hist = GOLDEN_PINS[radix][largest]
            assert passes == want_passes, f"radix {radix} largest={largest}"
            assert hist == want_hist, f"radix {radix} largest={largest}"
            # energy is derived, not independent: pin the composition
            assert abs(energy - price_compare(want_hist, radix)) < 1e-24


if __name__ == "__main__":
    for name, fn in sorted(globals().items()):
        if name.startswith("test_") and callable(fn):
            fn()
            print(f"{name} ok")
    print("all search_port tests passed")
