"""The modelcheck port itself: clean sweeps on two of the pinned bounded
scenarios (over every signature->shard routing, which covers the Rust
SipHash routing as one point), plus fault injections proving the checker
actually catches violations rather than vacuously passing."""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import modelcheck_port as mc


def test_smoke_scenario_clean_under_every_routing():
    # scenario D of shard_modelcheck.rs: 2 shards, 1 job + 1 program, steal
    sc = mc.mixed(2, 2, 2, True, 1, 1, 1, 1)
    reports = mc.sweep(sc, 1)
    assert len(reports) == 2  # one signature x two shards
    for rep in reports:
        assert 40 <= rep.states <= 42
        assert rep.depth == 7
        assert rep.goals == 1
        assert rep.terminal == 1


def test_mixed_scenario_clean_under_every_routing():
    # scenario A: 2 shards, 2 producers, 3 jobs + 1 program, 2 signatures
    sc = mc.mixed(2, 2, 2, True, 2, 3, 1, 2)
    reports = mc.sweep(sc, 2)
    assert len(reports) == 4
    for rep in reports:
        assert 508 <= rep.states <= 605
        assert rep.goals == 1
        assert rep.terminal == 1


class DuplicatedSubmit(mc.SystemMachine):
    """Tampered machine: producer 0's first submission lands twice."""

    def transition(self, st, action):
        nxt = super().transition(st, action)
        if action == ("submit", 0) and st[0][0] == 0:
            queues = [list(q) for q in nxt[2]]
            for q in queues:
                if 0 in q:
                    q.append(0)
            return nxt[:2] + (tuple(tuple(q) for q in queues),) + nxt[3:]
        return nxt


def test_checker_catches_a_duplicated_submission():
    sc = mc.mixed(2, 2, 2, True, 1, 1, 1, 1)
    with pytest.raises(mc.Violation, match="no-duplication"):
        mc.explore(DuplicatedSubmit(sc, lambda s: 0))


class NeverCloses(mc.SystemMachine):
    """Tampered machine: the close action never becomes available, so the
    drained-and-closed goal is unreachable."""

    def actions(self, st):
        return [a for a in super().actions(st) if a[0] != "close"]


def test_checker_catches_an_unreachable_goal():
    sc = mc.mixed(2, 2, 2, True, 1, 1, 1, 1)
    with pytest.raises(mc.Violation, match="deadlock|liveness"):
        mc.explore(NeverCloses(sc, lambda s: 0))
