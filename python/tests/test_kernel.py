"""L1 correctness: the Pallas kernel against the pure-numpy oracle —
the CORE correctness signal for every AOT artifact. Hypothesis sweeps
shapes, radices, functions and modes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ap_pass import ROW_BLOCK, apply_lut
from compile.kernels.ref import apply_lut_ref
from compile.luts import build_lut

FUNCS = ["add", "sub", "mac"]


def random_state(rng: np.random.Generator, rows: int, radix: int) -> np.ndarray:
    return rng.integers(0, radix, size=(rows, 3), dtype=np.int32)


@pytest.mark.parametrize("fn", FUNCS)
@pytest.mark.parametrize("radix", [2, 3])
@pytest.mark.parametrize("blocked", [False, True])
def test_kernel_matches_ref(fn, radix, blocked):
    lut = build_lut(fn, radix, blocked)
    rng = np.random.default_rng(42)
    state = random_state(rng, ROW_BLOCK, radix)
    got_state, got_hist, got_sets = apply_lut(state, lut)
    ref_state, ref_hist, ref_sets = apply_lut_ref(state, lut)
    np.testing.assert_array_equal(np.asarray(got_state), ref_state)
    np.testing.assert_array_equal(np.asarray(got_hist), ref_hist)
    np.testing.assert_array_equal(np.asarray(got_sets), ref_sets)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    blocks=st.integers(1, 3),
    radix=st.sampled_from([2, 3, 4]),
    fn=st.sampled_from(FUNCS),
    blocked=st.booleans(),
)
def test_kernel_matches_ref_hypothesis(seed, blocks, radix, fn, blocked):
    """Property sweep over shapes/dtypes: multi-block grids included."""
    lut = build_lut(fn, radix, blocked)
    rng = np.random.default_rng(seed)
    rows = blocks * ROW_BLOCK
    state = random_state(rng, rows, radix)
    got_state, got_hist, got_sets = apply_lut(state, lut)
    ref_state, ref_hist, ref_sets = apply_lut_ref(state, lut)
    np.testing.assert_array_equal(np.asarray(got_state), ref_state)
    np.testing.assert_array_equal(np.asarray(got_hist), ref_hist)
    np.testing.assert_array_equal(np.asarray(got_sets), ref_sets)


def test_stats_shape_and_conservation():
    """Histogram mass = rows per pass; sets bounded by rows × write_dim."""
    lut = build_lut("add", 3, blocked=True)
    rng = np.random.default_rng(7)
    state = random_state(rng, ROW_BLOCK, 3)
    _, hist, sets = apply_lut(state, lut)
    hist, sets = np.asarray(hist), np.asarray(sets)
    assert hist.shape == (21, 4)
    assert (hist.sum(axis=1) == ROW_BLOCK).all()
    assert sets.shape == (21,)
    assert (sets >= 0).all() and sets.sum() <= ROW_BLOCK * 3 * 21


def test_single_digit_add_all_states():
    """Exhaustive 27-state check against the truth table (the §IV example
    dims: written digits equal (S, C_out) for every stored triplet)."""
    lut = build_lut("add", 3, blocked=False)
    states = np.array(
        [[a, b, c] for a in range(3) for b in range(3) for c in range(3)],
        dtype=np.int32,
    )
    reps = ROW_BLOCK // len(states) + 1
    state = np.tile(states, (reps, 1))[:ROW_BLOCK]
    out, _, _ = apply_lut(state, lut)
    out = np.asarray(out)
    total = state.sum(axis=1)
    np.testing.assert_array_equal(out[:, 1], total % 3)
    np.testing.assert_array_equal(out[:, 2], total // 3)


def test_rejects_unpadded_rows():
    lut = build_lut("add", 3, blocked=False)
    with pytest.raises(AssertionError):
        apply_lut(np.zeros((ROW_BLOCK + 1, 3), dtype=np.int32), lut)
