"""AOT pipeline smoke tests: lowering produces parseable HLO text with the
expected I/O signature (checked structurally, not by re-executing — the
execution check is the Rust integration test against the native sim)."""

import re

import pytest

from compile.aot import lower_variant, variant_name


@pytest.mark.parametrize(
    "fn,mode,radix,rows,digits",
    [("add", "blocked", 3, 256, 4), ("add", "non_blocked", 2, 256, 8)],
)
def test_lowering_produces_hlo_text(fn, mode, radix, rows, digits):
    text, meta = lower_variant(fn, mode, radix, rows, digits)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # input parameter: rows × (2p+1) int32
    assert f"s32[{rows},{2 * digits + 1}]" in text
    assert meta["passes"] >= 1 and meta["groups"] >= 1
    if mode == "blocked" and radix == 3 and fn == "add":
        assert meta["passes"] == 21 and meta["groups"] == 9


def test_output_tuple_shapes():
    """Lowered module returns (array, hist, sets) as a tuple."""
    text, meta = lower_variant("add", "blocked", 3, 256, 4)
    root = re.search(r"entry_computation_layout=\{.*?->\((.*?)\)\}", text)
    assert root, "tuple return signature missing"
    sig = root.group(1)
    assert f"s32[256,9]" in sig  # array'
    assert f"s32[4,21,4]" in sig  # hist [p, P, classes]
    assert f"s32[4,21]" in sig  # sets [p, P]


def test_variant_names_unique():
    from compile.aot import VARIANTS

    names = [variant_name(*v) for v in VARIANTS]
    assert len(names) == len(set(names))
