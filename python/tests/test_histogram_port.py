"""Validation suite for the LatencyHistogram port (histogram_port.py).

Run directly: ``python3 python/tests/test_histogram_port.py``.

Three layers:
  1. structural properties of the bucket layout (continuity, round-trips,
     bounded relative width) over exhaustive small values and random u64s;
  2. quantile accuracy vs a sorted-array reference on random workloads;
  3. the exact pinned cases asserted by the Rust unit tests in
     ``rust/src/serving/histogram.rs`` — if these move, the Rust pins
     must move with them.
"""

import os
import random
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from histogram_port import (  # noqa: E402
    SUBS,
    U64_MAX,
    LatencyHistogram,
    bucket_bounds,
    bucket_of,
)


def test_bucket_layout():
    # Exhaustive continuity for small values: consecutive values map to the
    # same or the next bucket, and each value lies inside its bucket bounds.
    prev = None
    for v in range(0, 1 << 14):
        b = bucket_of(v)
        lo, hi = bucket_bounds(b)
        assert lo <= v < hi, (v, b, lo, hi)
        if prev is not None:
            assert b in (prev, prev + 1), (v, prev, b)
        prev = b

    # Random u64 round-trips, including the extremes.
    rng = random.Random(0x5EED)
    samples = [0, 1, SUBS - 1, SUBS, U64_MAX] + [
        rng.randrange(U64_MAX + 1) for _ in range(20000)
    ]
    for v in samples:
        b = bucket_of(v)
        lo, hi = bucket_bounds(b)
        assert lo <= v < hi or (v == U64_MAX and lo <= v), (v, b, lo, hi)
        # Relative bucket width is bounded by 1/SUBS above the exact range.
        if v >= SUBS:
            assert (hi - lo) * SUBS <= lo + (hi - lo), (v, lo, hi)

    # The top bucket index bounds the backing array size.
    assert bucket_of(U64_MAX) == (58 + 1) * SUBS + 31 == 1919
    print("bucket layout ok")


def reference_quantile(sorted_vals, q):
    """Nearest-rank-with-interpolation reference (numpy 'linear' method)."""
    n = len(sorted_vals)
    rank = q * (n - 1)
    lo = int(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


def test_quantile_accuracy():
    rng = random.Random(0xC0DE)
    for case in range(200):
        n = rng.randrange(1, 400)
        dist = rng.choice(["uniform", "lognorm", "spike"])
        if dist == "uniform":
            vals = [rng.randrange(1, 10_000_000) for _ in range(n)]
        elif dist == "lognorm":
            vals = [int(rng.lognormvariate(10, 2)) + 1 for _ in range(n)]
        else:
            base = rng.randrange(1, 1_000_000)
            vals = [base] * (n - n // 10) + [
                base * rng.randrange(2, 50) for _ in range(n // 10)
            ]
        h = LatencyHistogram()
        for v in vals:
            h.record(v)
        s = sorted(vals)
        for q in (0.0, 0.5, 0.9, 0.95, 0.99, 1.0):
            est = h.quantile_ns(q)
            ref = reference_quantile(s, q)
            # The estimate must land within one bucket width (1/SUBS
            # relative) of the true value's neighbourhood.
            lo_ok = s[0] * (1 - 2 / SUBS) - 1
            hi_ok = s[-1] * (1 + 2 / SUBS) + 1
            assert lo_ok <= est <= hi_ok, (case, q, est, s[0], s[-1])
            tol = max(2.0, ref * (2 / SUBS))
            # Compare against the reference's bracketing order statistics to
            # absorb rank-rounding differences.
            rank = q * (n - 1)
            lo_stat = s[int(rank)]
            hi_stat = s[min(int(rank) + 1, n - 1)]
            lo_bound = lo_stat - max(2.0, lo_stat * (2 / SUBS))
            hi_bound = hi_stat + max(2.0, hi_stat * (2 / SUBS))
            assert lo_bound <= est <= hi_bound, (
                case, dist, q, est, ref, lo_stat, hi_stat,
            )
    print("quantile accuracy ok")


def test_merge_equals_record_all():
    rng = random.Random(7)
    a, b, all_ = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
    for _ in range(500):
        v = rng.randrange(1, 1_000_000)
        (a if rng.random() < 0.5 else b).record(v)
        all_.record(v)
    a.merge(b)
    assert a.buckets == all_.buckets[: len(a.buckets)]
    assert a.count == all_.count and a.total_ns == all_.total_ns
    assert a.min_ns == all_.min_ns and a.max_ns == all_.max_ns
    for q in (0.5, 0.95, 0.99):
        assert a.quantile_ns(q) == all_.quantile_ns(q)
    print("merge ok")


def test_pinned_cases():
    """The exact constants pinned by the Rust unit tests."""
    # Empty -> None.
    assert LatencyHistogram().quantile_ns(0.5) is None

    # Single sample: exact (interpolation clamps to [min, max]).
    h = LatencyHistogram()
    h.record(1000)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.quantile_ns(q) == 1000.0, h.quantile_ns(q)

    # All-equal: exact at every quantile.
    h = LatencyHistogram()
    for _ in range(100):
        h.record(7)
    for q in (0.0, 0.5, 0.95, 1.0):
        assert h.quantile_ns(q) == 7.0

    # Mid-bucket interpolation: 0..=99 ns. Values 64..99 share width-2
    # buckets, so p95/p99 interpolate inside a bucket.
    h = LatencyHistogram()
    for v in range(100):
        h.record(v)
    p50 = h.quantile_ns(0.50)
    p95 = h.quantile_ns(0.95)
    p99 = h.quantile_ns(0.99)
    assert abs(p50 - 50.0) < 1e-9, p50
    assert abs(p95 - 94.55) < 1e-9, p95
    assert abs(p99 - 98.51) < 1e-9, p99

    # Two samples in one width-16 bucket ([992, 1008)): midpoint
    # interpolation, still clamped to the observed extremes.
    h = LatencyHistogram()
    h.record(992)
    h.record(1007)
    assert bucket_of(992) == bucket_of(1007) == 190
    assert h.quantile_ns(0.5) == 1000.0
    assert abs(h.quantile_ns(0.99) - 1003.92) < 1e-9, h.quantile_ns(0.99)
    assert h.quantile_ns(0.0) == 992.0   # clamped to min
    assert h.quantile_ns(1.0) == 1007.0  # clamped to max

    # Mean / extremes.
    assert h.mean_ns() == (992 + 1007) / 2
    print("pinned cases ok")


if __name__ == "__main__":
    test_bucket_layout()
    test_quantile_accuracy()
    test_merge_equals_record_all()
    test_pinned_cases()
    print("ALL HISTOGRAM PORT TESTS PASSED")
