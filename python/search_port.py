"""Exact Python port of the in-engine content-addressable search path.

Mirrors ``rust/src/ap/search.rs`` — both the host oracles
(``host_exact``/``host_nearest``/``host_extreme``/``host_topk``) and the
engine's compare schedules with their event accounting:

* **Exact match** — one modeled compare cycle whose mismatch histogram
  buckets all segment rows by their mismatching-digit count.
* **Nearest match** — one single-column compare cycle per digit, each
  recording ``[matches, rows - matches]``.
* **Min/Max** — most-significant-digit-first candidate elimination.
  Per digit, probe values run in scan order (min: ``0, 1, ...``; max:
  ``n-1, n-2, ...``) until some candidate matches; the *last* scan value
  is never probed (implied — at radix 2 the classic bit-serial schedule
  costs one compare per bit), and elimination exits early once a single
  candidate remains. Every probe is recorded over ALL segment rows
  (the CAM drives the whole array; candidate gating is tag logic).
* **TopK** — repeated extreme extraction over a shrinking pool.

Words are little-endian digit lists; ``None`` is a stored don't-care
(matches every probe, so under elimination it acts as 0 for min and
``n-1`` for max — the same substitution ``effective_value`` makes).

The energy model is the §VI-A composition ported from
``rust/src/energy/model.rs``: per-mismatch-class compare energies times
the histogram, plus 1 nJ per write op — and search never writes, so the
write term is identically zero. Modeled delay is the compare-pass count.

This port is the derivation path for the Min/Max golden pins in
``rust/tests/golden_values.rs`` (see ``python/tests/test_search_port.py``,
which pins the same numbers), runnable in toolchain-less containers.
"""

# ---------------------------------------------------------------------------
# energy model constants (rust/src/energy/model.rs)
# ---------------------------------------------------------------------------

COMPARE_TERNARY = [3.60e-15, 18.49e-15, 25.66e-15, 29.05e-15]
COMPARE_BINARY = [1.85e-15, 17.65e-15, 25.26e-15, 28.86e-15]
WRITE_OP_ENERGY = 1e-9


def compare_class(table, k):
    """``CompareEnergy::class``: saturate past the last entry."""
    return table[k] if k < len(table) else table[-1]


def price_compare(hist, radix):
    """Compare energy (J) of a mismatch histogram under the engine's
    model choice: the binary table at radix 2, ternary otherwise.
    Search ops never write, so this is the whole energy."""
    table = COMPARE_BINARY if radix == 2 else COMPARE_TERNARY
    return sum(count * compare_class(table, k) for k, count in enumerate(hist))


class Stats:
    """The search-relevant slice of ``ApStats``: compare cycles and the
    mismatch histogram (search records no writes, ever)."""

    def __init__(self):
        self.compare_cycles = 0
        self.hist = []

    def record_compare(self, hist):
        self.compare_cycles += 1
        if len(self.hist) < len(hist):
            self.hist += [0] * (len(hist) - len(self.hist))
        for k, v in enumerate(hist):
            self.hist[k] += v


# ---------------------------------------------------------------------------
# host oracles (the pure references)
# ---------------------------------------------------------------------------

def digit_matches(a, b):
    return a is None or b is None or a == b


def host_exact(values, key):
    """Ascending rows equal to ``key`` under wildcard matching."""
    return [
        r for r, w in enumerate(values)
        if all(digit_matches(a, b) for a, b in zip(w, key))
    ]


def host_nearest(values, key):
    """``(ascending rows at minimum digit distance, that distance)``."""
    def dist(w):
        return sum(0 if digit_matches(a, b) else 1 for a, b in zip(w, key))
    best = min(dist(w) for w in values)
    return [r for r, w in enumerate(values) if dist(w) == best], best


def effective_value(word, radix, largest):
    """Don't-care digits assume the best value for the scan direction."""
    acc = 0
    for d in reversed(word):
        e = (radix - 1 if largest else 0) if d is None else d
        acc = acc * radix + e
    return acc


def host_extreme(values, radix, largest):
    """Ascending rows holding the extreme effective value."""
    eff = [effective_value(w, radix, largest) for w in values]
    best = max(eff) if largest else min(eff)
    return [r for r, e in enumerate(eff) if e == best]


def host_topk(values, radix, k, largest):
    """``min(k, rows)`` rows ranked by effective value, ties ascending."""
    eff = [effective_value(w, radix, largest) for w in values]
    order = sorted(range(len(values)),
                   key=lambda r: (-eff[r] if largest else eff[r], r))
    return order[: min(k, len(values))]


# ---------------------------------------------------------------------------
# the engine schedules, with exact event accounting
# ---------------------------------------------------------------------------

def search_exact(values, key, stats=None):
    """One compare cycle; ``hist[k]`` = rows with k mismatching digits."""
    stats = stats if stats is not None else Stats()
    misses = [
        sum(0 if digit_matches(a, b) else 1 for a, b in zip(w, key))
        for w in values
    ]
    hist = [0] * (len(key) + 1)
    for m in misses:
        hist[m] += 1
    stats.record_compare(hist)
    return [r for r, m in enumerate(misses) if m == 0], stats


def search_nearest(values, key, stats=None):
    """p single-column compare cycles; rows at minimum digit distance."""
    stats = stats if stats is not None else Stats()
    rows = len(values)
    for d, kd in enumerate(key):
        m = sum(1 for w in values if digit_matches(w[d], kd))
        stats.record_compare([m, rows - m])
    hit_rows, best = host_nearest(values, key)
    return hit_rows, best, stats


def _probe(values, d, v, stats):
    """One single-column compare over all rows: matching row set."""
    matched = {r for r, w in enumerate(values) if w[d] is None or w[d] == v}
    stats.record_compare([len(matched), len(values) - len(matched)])
    return matched


def _scan(radix, largest):
    """``SearchKernel`` probe order; the last value is implied."""
    order = list(range(radix - 1, -1, -1)) if largest else list(range(radix))
    return order[: radix - 1]


def _eliminate(values, radix, largest, cands, stats):
    """MS-digit-first elimination over candidate rows ``cands``."""
    p = len(values[0])
    cands = list(cands)
    for d in reversed(range(p)):
        if len(cands) <= 1:
            break  # early exit: a lone candidate is already the extreme
        for v in _scan(radix, largest):
            matched = _probe(values, d, v, stats)
            survivors = [r for r in cands if r in matched]
            if survivors:
                cands = survivors
                break
            # all candidates missed: keep scanning; if every probe
            # misses, all candidates hold the implied last value
    return cands


def search_extreme(values, radix, largest, stats=None):
    """Min/Max: ``(ascending extreme rows, stats)``."""
    stats = stats if stats is not None else Stats()
    return _eliminate(values, radix, largest, range(len(values)), stats), stats


def search_topk(values, radix, k, largest, stats=None):
    """TopK: repeated extraction; ``min(k, rows)`` rows in rank order."""
    stats = stats if stats is not None else Stats()
    want = min(k, len(values))
    pool = list(range(len(values)))
    ranked = []
    while len(ranked) < want:
        winners = _eliminate(values, radix, largest, pool, stats)
        for w in winners:
            if len(ranked) == want:
                break
            ranked.append(w)
        pool = [r for r in pool if r not in winners]
    return ranked, stats


# ---------------------------------------------------------------------------
# golden-pin derivation (the fixture rust/tests/golden_values.rs shares)
# ---------------------------------------------------------------------------

GOLDEN_ROWS = 48
GOLDEN_DIGITS = 4


def golden_values(radix):
    """The deterministic golden fixture: row r stores
    ``(r * 37 + 11) mod radix**4`` as a 4-digit little-endian word —
    the same formula `golden_search_elimination_pins` builds with
    ``Word::from_u128``. No RNG, so both languages agree by construction."""
    span = radix ** GOLDEN_DIGITS
    out = []
    for r in range(GOLDEN_ROWS):
        v = (r * 37 + 11) % span
        digits = []
        for _ in range(GOLDEN_DIGITS):
            digits.append(v % radix)
            v //= radix
        out.append(digits)
    return out


def golden_extreme_pin(radix, largest):
    """``(passes, hist, compare_energy)`` of Min/Max over the fixture."""
    values = golden_values(radix)
    rows, stats = search_extreme(values, radix, largest)
    assert rows == host_extreme(values, radix, largest)
    return stats.compare_cycles, list(stats.hist), price_compare(stats.hist, radix)


if __name__ == "__main__":
    for radix in (2, 3, 4, 5):
        for largest in (False, True):
            passes, hist, energy = golden_extreme_pin(radix, largest)
            print(
                f"radix {radix} {'max' if largest else 'min'}: "
                f"passes={passes} hist={hist} compare_energy={energy:.6e}"
            )
