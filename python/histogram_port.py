"""Exact Python port of ``rust/src/serving/histogram.rs``.

The Rust crate's streaming latency histogram (``LatencyHistogram``) is an
HDR-style log-linear histogram: values below ``SUBS`` get exact width-1
buckets, and every power-of-two era above that is split into ``SUBS``
equal-width sub-buckets, bounding relative bucket width at 1/SUBS (~3%).
Quantiles interpolate inside the selected bucket and clamp to the exact
observed [min, max].

This port mirrors the Rust arithmetic operation-for-operation so the test
suite can (a) property-check the quantile estimate against a sorted-array
reference without a Rust toolchain and (b) pin the exact constants asserted
by the Rust unit tests.
"""

SUB_BITS = 5
SUBS = 1 << SUB_BITS  # 32 sub-buckets per power-of-two era
U64_MAX = (1 << 64) - 1


def bucket_of(ns: int) -> int:
    """Bucket index for a latency of ``ns`` nanoseconds.

    Values 0..SUBS-1 land in exact width-1 buckets; above that, era
    ``shift`` (values with top bit ``SUB_BITS + shift``) is split into
    SUBS sub-buckets of width ``2**shift``.
    """
    assert 0 <= ns <= U64_MAX
    if ns < SUBS:
        return ns
    top = ns.bit_length() - 1          # 63 - leading_zeros
    shift = top - SUB_BITS
    return (shift + 1) * SUBS + ((ns >> shift) - SUBS)


def bucket_bounds(i: int):
    """Half-open value range ``[lo, hi)`` covered by bucket ``i``."""
    if i < SUBS:
        return (i, i + 1)
    era = i // SUBS - 1
    off = i % SUBS
    lo = (SUBS + off) << era
    return (lo, lo + (1 << era))


class LatencyHistogram:
    def __init__(self):
        self.buckets = []
        self.count = 0
        self.total_ns = 0
        self.min_ns = U64_MAX
        self.max_ns = 0

    def record(self, ns: int):
        ns = min(max(ns, 0), U64_MAX)
        b = bucket_of(ns)
        if b >= len(self.buckets):
            self.buckets.extend([0] * (b + 1 - len(self.buckets)))
        self.buckets[b] += 1
        self.count += 1
        self.total_ns += ns
        self.min_ns = min(self.min_ns, ns)
        self.max_ns = max(self.max_ns, ns)

    def merge(self, other: "LatencyHistogram"):
        if other.count == 0:
            return
        if len(other.buckets) > len(self.buckets):
            self.buckets.extend([0] * (len(other.buckets) - len(self.buckets)))
        for i, c in enumerate(other.buckets):
            self.buckets[i] += c
        self.count += other.count
        self.total_ns += other.total_ns
        self.min_ns = min(self.min_ns, other.min_ns)
        self.max_ns = max(self.max_ns, other.max_ns)

    def mean_ns(self):
        if self.count == 0:
            return None
        return self.total_ns / self.count

    def quantile_ns(self, q: float):
        """Estimated value at quantile ``q`` in [0, 1], or None when empty.

        Rank semantics match ``rank = q * (n - 1)`` over the sorted sample
        order; the estimate interpolates at the midpoint offset inside the
        owning bucket and clamps to the exact observed extremes so empty /
        single-sample / all-equal cases are exact.
        """
        if self.count == 0:
            return None
        q = min(max(q, 0.0), 1.0)
        if q == 0.0:
            return float(self.min_ns)
        if q == 1.0:
            return float(self.max_ns)
        rank = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            if rank < cum + c:
                lo, hi = bucket_bounds(i)
                frac = ((rank - cum) + 0.5) / c
                est = lo + frac * (hi - lo)
                return min(max(est, float(self.min_ns)), float(self.max_ns))
            cum += c
        # Unreachable when counts are consistent; mirror the Rust fallback.
        return float(self.max_ns)
