//! Circuit design-space exploration (§VI-A): sweep the memristor dynamic
//! range / compare energy over (R_L, α) on the MNA matchline simulator and
//! pick the paper's design point — the narrative behind Figs. 6 and 7.
//!
//! Run: `cargo run --release --example circuit_dse`

use mvap::circuit::{sweep_design_space, CellTech, MatchClass, MatchlineSim};
use mvap::exp::circuit_dse;
use mvap::util::table::fnum;

fn main() {
    println!("sweeping R_L ∈ {{20,30,50,100}} kΩ × α ∈ {{10..50}} on the MNA matchline model…\n");
    let sweep = sweep_design_space(CellTech::ternary_default());

    let (fig6, _) = circuit_dse::fig6(&sweep);
    fig6.print();
    println!();
    let (fig7, _) = circuit_dse::fig7(&sweep);
    fig7.print();

    let best = sweep.best();
    println!(
        "\nchosen design point (max DR, lowest compare energy at that R_L): \
         R_L = {} kΩ, α = {} → DR = {} mV",
        best.r_l / 1e3,
        best.alpha,
        fnum(best.dr * 1e3, 1)
    );
    println!("paper's choice: (20 kΩ, 50) with DR ≈ 240 mV — §VI-A\n");

    // The ML voltage story of §II-A / Table III, from the transient itself.
    let sim = MatchlineSim { tech: CellTech::ternary_default(), masked_cells: 3 };
    println!("matchline voltage after 1 ns evaluate (V_DD = 0.8 V):");
    for k in 0..=3 {
        let label = ["full match", "1 mismatch", "2 mismatches", "3 mismatches"][k];
        println!(
            "  {label:<13} V_ML = {} V   E_compare = {} fJ",
            fnum(sim.ml_voltage(MatchClass(k)), 3),
            fnum(sim.compare_energy(MatchClass(k)) * 1e15, 2)
        );
    }
    let d = circuit_dse::alpha_drops(&sweep);
    println!(
        "\nα=10→50 compare-energy drops at R_L = 20 kΩ: fm −{}%, 1mm −{}%, 2mm −{}%, 3mm −{}%",
        fnum(d[0] * 100.0, 1),
        fnum(d[1] * 100.0, 1),
        fnum(d[2] * 100.0, 1),
        fnum(d[3] * 100.0, 1)
    );
    println!("paper: −71.61%, −22.27%, −9.45%, −4.37% (§VI-A)");
}
