//! Associative key→value store: records live in the CAM as
//! `[key digits | value digits]` words, and a lookup is ONE in-engine
//! exact-match search whose probe wildcards the value field — the
//! content-addressable idiom the paper's array is built for. No index,
//! no hashing: the key field itself is the address.
//!
//! Run: `cargo run --release --example assoc_kv`

use mvap::coordinator::{Job, NativeBackend, VectorEngine};
use mvap::mvl::{Radix, Word, DONT_CARE};
use mvap::util::Rng;
use std::collections::HashMap;

const KEY_DIGITS: usize = 6; // high field: the associative "address"
const VAL_DIGITS: usize = 6; // low field: the payload
const RECORDS: usize = 512;

/// Pack (key, value) into one stored word: value in the low digits,
/// key in the high digits (digit order is little-endian).
fn record(key: &[u8], val: &[u8], radix: Radix) -> Word {
    let digits: Vec<u8> = val.iter().chain(key).copied().collect();
    Word::from_digits(digits, radix)
}

fn main() -> anyhow::Result<()> {
    let radix = Radix::TERNARY;
    let mut rng = Rng::new(42);

    // 1. Build RECORDS entries with distinct keys and random payloads.
    let mut oracle: HashMap<Vec<u8>, Vec<u8>> = HashMap::new();
    while oracle.len() < RECORDS {
        oracle
            .entry(rng.number(KEY_DIGITS, radix.n()))
            .or_insert_with(|| rng.number(VAL_DIGITS, radix.n()));
    }
    let entries: Vec<(Vec<u8>, Vec<u8>)> =
        oracle.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    let stored: Vec<Word> =
        entries.iter().map(|(k, v)| record(k, v, radix)).collect();
    println!(
        "{RECORDS} records resident: {KEY_DIGITS}-trit keys, {VAL_DIGITS}-trit values"
    );

    // 2. Look half the keys up. The probe carries the key in the high
    //    field and DONT_CARE across the value field, so a single compare
    //    schedule matches key-equality regardless of the stored payload.
    let mut engine = VectorEngine::new(Box::new(NativeBackend::default()));
    let lookups = RECORDS / 2;
    for (id, (key, want_val)) in entries.iter().take(lookups).enumerate() {
        let mut probe = vec![DONT_CARE; VAL_DIGITS];
        probe.extend_from_slice(key);
        let probe = Word::from_digits_wild(probe, radix);
        let job = Job::search(id as u64, radix, stored.clone(), probe, false, vec![]);
        let res = engine.execute(&job)?;
        let hits = &res.hits[0];
        assert_eq!(hits.rows.len(), 1, "keys are unique — exactly one hit");
        let got_val = &hits.values[0].digits()[..VAL_DIGITS];
        assert_eq!(got_val, want_val.as_slice(), "payload mismatch for key {key:?}");
    }
    println!("{lookups} lookups answered and verified ✓");

    // 3. A miss: wildcarded probe for a key that was never stored.
    let absent = loop {
        let k = rng.number(KEY_DIGITS, radix.n());
        if !oracle.contains_key(&k) {
            break k;
        }
    };
    let mut probe = vec![DONT_CARE; VAL_DIGITS];
    probe.extend_from_slice(&absent);
    let job = Job::search(
        lookups as u64,
        radix,
        stored,
        Word::from_digits_wild(probe, radix),
        false,
        vec![],
    );
    let res = engine.execute(&job)?;
    assert!(res.hits[0].rows.is_empty(), "absent key must miss");
    println!("absent key misses cleanly (empty hit set) ✓");
    println!(
        "\nper-lookup model: {} compare pass(es), {:.3e} J, {} cycle(s) — \
         independent of where the record sits",
        res.hits[0].passes,
        res.energy.total(),
        res.delay_cycles,
    );
    Ok(())
}
