//! Quickstart: generate the ternary full-adder LUTs, run a 20-trit vector
//! addition on the associative processor, and report values, energy and
//! delay — the paper's core loop in ~50 lines of user code.
//!
//! Run: `cargo run --release --example quickstart`

use mvap::coordinator::{Job, NativeBackend, OpKind, VectorEngine};
use mvap::diagram::StateDiagram;
use mvap::func::full_add;
use mvap::lutgen::{generate_blocked, generate_non_blocked};
use mvap::mvl::{Radix, Word};
use mvap::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. The paper's LUTs, generated automatically from the truth table.
    let diagram = StateDiagram::build(full_add(Radix::TERNARY))?;
    let non_blocked = generate_non_blocked(&diagram);
    let blocked = generate_blocked(&diagram);
    println!(
        "TFA LUTs: non-blocked = {} passes/{} writes, blocked = {} passes/{} writes per trit",
        non_blocked.passes.len(),
        non_blocked.num_groups,
        blocked.passes.len(),
        blocked.num_groups
    );
    println!(
        "cycle break: {:?} (the paper's 101 → 020 widened write)\n",
        diagram
            .rewrites()
            .iter()
            .map(|&(x, y, z)| format!(
                "{}→{} rewritten to {}→{}",
                diagram.table().fmt_state(x),
                diagram.table().fmt_state(y),
                diagram.table().fmt_state(x),
                diagram.table().fmt_state(z)
            ))
            .collect::<Vec<_>>()
    );

    // 2. A 20-trit vector addition over 1024 rows.
    let radix = Radix::TERNARY;
    let (rows, digits) = (1024, 20);
    let mut rng = Rng::new(42);
    let a: Vec<Word> = (0..rows)
        .map(|_| Word::from_digits(rng.number(digits, 3), radix))
        .collect();
    let b: Vec<Word> = (0..rows)
        .map(|_| Word::from_digits(rng.number(digits, 3), radix))
        .collect();

    let mut engine = VectorEngine::new(Box::new(NativeBackend::default()));
    let job = Job::new(1, OpKind::Add, radix, true, a.clone(), b.clone());
    let result = engine.execute(&job)?;

    // 3. Verify against plain integer arithmetic and report.
    for r in 0..rows {
        let (expect, carry) = a[r].add_ref(&b[r], 0);
        assert_eq!(result.values[r], (expect, carry), "row {r}");
    }
    println!("{} additions verified against the software oracle ✓", rows);
    println!("example row: {} + {} = {} (carry {})", a[0], b[0], result.values[0].0, result.values[0].1);
    println!("\nmodeled metrics for the whole batch (row-parallel):");
    println!("  energy : {:.3e} J ({} set/reset ops + compares)", result.energy.total(), result.energy.write_ops);
    println!("  delay  : {} clock cycles (blocked; non-blocked would be 840)", result.delay_cycles);
    println!("  wall   : {:?} on the functional simulator", result.elapsed);
    Ok(())
}
