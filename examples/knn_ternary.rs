//! Nearest-neighbour classification over ternary embeddings: a labelled
//! set of {0,1,2}-valued vectors sits in the CAM, and each query is one
//! in-engine nearest-match search — the array reports every row at the
//! minimum digit (Hamming) distance in a handful of compare passes,
//! without streaming the dataset past the host.
//!
//! Run: `cargo run --release --example knn_ternary`

use mvap::ap::host_nearest;
use mvap::coordinator::{Job, NativeBackend, VectorEngine};
use mvap::mvl::{Radix, Word};
use mvap::util::Rng;

const DIM: usize = 24; // embedding digits per vector
const CLASSES: usize = 8;
const PER_CLASS: usize = 64;
const QUERIES: usize = 48;
const NOISE_DIGITS: usize = 3; // digits flipped to make samples / queries

/// Copy `proto` with `flips` random digits re-rolled.
fn perturb(proto: &[u8], flips: usize, rng: &mut Rng, radix: Radix) -> Vec<u8> {
    let mut v = proto.to_vec();
    for _ in 0..flips {
        let i = rng.below(DIM as u64) as usize;
        v[i] = (v[i] + 1 + rng.below(radix.n() as u64 - 1) as u8) % radix.n();
    }
    v
}

fn main() -> anyhow::Result<()> {
    let radix = Radix::TERNARY;
    let mut rng = Rng::new(7);

    // 1. Dataset: CLASSES prototypes, PER_CLASS noisy samples each.
    //    Row r holds a sample of class r / PER_CLASS.
    let protos: Vec<Vec<u8>> = (0..CLASSES).map(|_| rng.number(DIM, radix.n())).collect();
    let dataset: Vec<Word> = protos
        .iter()
        .flat_map(|p| {
            (0..PER_CLASS)
                .map(|_| Word::from_digits(perturb(p, NOISE_DIGITS, &mut rng, radix), radix))
                .collect::<Vec<_>>()
        })
        .collect();
    println!(
        "{} embeddings resident ({CLASSES} classes × {PER_CLASS}, {DIM} trits each)",
        dataset.len()
    );

    // 2. Classify queries: nearest-match search returns the full set of
    //    minimum-distance rows; the label is their majority class.
    let mut engine = VectorEngine::new(Box::new(NativeBackend::default()));
    let mut correct = 0usize;
    let mut passes = 0u64;
    for q in 0..QUERIES {
        let class = q % CLASSES;
        let query =
            Word::from_digits(perturb(&protos[class], NOISE_DIGITS, &mut rng, radix), radix);
        let job = Job::search(q as u64, radix, dataset.clone(), query.clone(), true, vec![]);
        let res = engine.execute(&job)?;
        let hits = &res.hits[0];
        passes += hits.passes;

        // engine hit set ≡ the host linear scan, at the same distance
        let (want_rows, want_dist) = host_nearest(&dataset, &query);
        assert_eq!(hits.rows, want_rows, "query {q}");
        assert_eq!(hits.distance, want_dist, "query {q}");

        let mut votes = [0usize; CLASSES];
        for &r in &hits.rows {
            votes[r / PER_CLASS] += 1;
        }
        let predicted = (0..CLASSES).max_by_key(|&c| votes[c]).unwrap();
        correct += (predicted == class) as usize;
    }
    println!(
        "{correct}/{QUERIES} queries classified correctly \
         (noise: {NOISE_DIGITS}/{DIM} digits re-rolled)"
    );
    println!(
        "every hit set matched the host linear scan ✓ \
         ({:.1} compare passes per query vs {} host word comparisons)",
        passes as f64 / QUERIES as f64,
        dataset.len(),
    );
    Ok(())
}
