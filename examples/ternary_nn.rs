//! End-to-end driver: a ternary neural-network layer computed **entirely
//! with AP operations**, on a real small workload.
//!
//! Workload: `y = W · x` for a 16×1024 ternary weight matrix and ternary
//! activations (the §I motivation: machine-learning kernels as massively
//! parallel digit-wise ops). Per output neuron, exactly **two jobs**:
//!
//!   1. **MAC job** — one AP row per input i holding `(W_ji, x_i, 0)`;
//!      the in-place `mac` LUT computes all 1024 products in one
//!      row-parallel op (products ≤ 4 = two trits: B + carry).
//!   2. **Reduce job** — one in-engine segmented tree reduction
//!      ([`mvap::coordinator::OpKind::Reduce`]): the engine folds all
//!      1024 partial products down to the dot product in ⌈log₂ 1024⌉ = 10
//!      pairwise rounds, moving rows between rounds with the plane-native
//!      row-movement primitive. No partial sum ever returns to the host —
//!      the pre-Reduce version of this example paid a full job round-trip
//!      per pairing round (10 Add jobs per neuron, with host reshaping
//!      between each).
//!
//! The run verifies against an integer reference, asserts the engine
//! executed exactly ⌈log₂ N⌉ reduction rounds per neuron, and reports the
//! paper's headline metrics (energy vs the binary AP, delay vs the
//! ternary CLA).
//!
//! Run: `cargo run --release --example ternary_nn`
//!      (`-- --backend native-bitsliced` for the digit-plane storage;
//!       Reduce jobs run on the native backends — PJRT artifacts cover
//!       element-wise ops only)

use mvap::baselines::cla_model;
use mvap::coordinator::{BackendKind, EngineService, Job, OpKind};
use mvap::mvl::{Radix, Word};
use mvap::util::cli::Args;
use mvap::util::Rng;
use std::path::PathBuf;

const INPUTS: usize = 1024;
const OUTPUTS: usize = 16;
/// Accumulator width: sums ≤ 1024·4 < 3^8.
const ACC_TRITS: usize = 8;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let backend: BackendKind = args
        .get_or("backend", "native")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    args.reject_unknown();
    if backend == BackendKind::Pjrt {
        anyhow::bail!(
            "the in-engine Reduce path is native-only — use --backend native or native-bitsliced"
        );
    }

    let radix = Radix::TERNARY;
    let mut rng = Rng::new(1234);
    // synthetic ternary layer: weights and activations ∈ {0, 1, 2}
    let weights: Vec<Vec<u8>> = (0..OUTPUTS).map(|_| rng.number(INPUTS, 3)).collect();
    let x: Vec<u8> = rng.number(INPUTS, 3);

    let workers = 4;
    let svc = EngineService::start_kind(workers, 16, backend, artifacts)?;
    println!(
        "ternary NN layer: {OUTPUTS} neurons × {INPUTS} inputs on the {} backend ({workers} workers)\n",
        match backend {
            BackendKind::Pjrt => unreachable!(),
            BackendKind::Native => "native simulator",
            BackendKind::NativeBitSliced => "native simulator (bit-sliced digit planes)",
        }
    );

    let started = std::time::Instant::now();
    let mut total_energy = 0.0f64;
    let mut total_cycles = 0u64;
    let mut outputs = Vec::new();
    let mut job_id = 0u64;

    for (j, w_row) in weights.iter().enumerate() {
        // --- stage 1: row-parallel products via the in-place MAC LUT ----
        let wa: Vec<Word> = w_row
            .iter()
            .map(|&w| Word::from_u128(w as u128, ACC_TRITS, radix))
            .collect();
        let xb: Vec<Word> = x
            .iter()
            .map(|&xi| Word::from_u128(xi as u128, ACC_TRITS, radix))
            .collect();
        job_id += 1;
        let res = svc.run(Job::new(job_id, OpKind::Mac, radix, true, wa, xb))?;
        total_energy += res.energy.total();
        total_cycles += res.delay_cycles;
        // The digit-wise MAC ripples the product's high trit into B's next
        // digit (digit 1 sees A₁·B₁ + carry = carry), so B already holds
        // the complete 2-trit product, zero-extended to ACC_TRITS.
        let partials: Vec<Word> = res.values.into_iter().map(|(w, _)| w).collect();

        // --- stage 2: ONE in-engine tree reduction ----------------------
        job_id += 1;
        let res = svc.run(Job::reduce(job_id, radix, true, partials, vec![]))?;
        total_energy += res.energy.total();
        total_cycles += res.delay_cycles;
        assert_eq!(res.values.len(), 1, "one segment, one sum");
        let y_j = res.values[0].0.to_u128() as u64;

        // verify against the integer reference
        let expect: u64 = w_row.iter().zip(&x).map(|(&w, &xi)| w as u64 * xi as u64).sum();
        assert_eq!(y_j, expect, "neuron {j}");
        outputs.push(y_j);
    }
    let wall = started.elapsed();
    let metrics = svc.shutdown();

    // exactly one MAC + one Reduce job per neuron, ⌈log₂ N⌉ rounds each
    assert_eq!(metrics.jobs, 2 * OUTPUTS as u64);
    let rounds_per_neuron = mvap::ap::fold_rounds(INPUTS) as u64; // 10
    assert_eq!(metrics.reduce_rounds, OUTPUTS as u64 * rounds_per_neuron);
    assert_eq!(
        metrics.reduce_rows_moved,
        (OUTPUTS * (INPUTS - 1)) as u64,
        "every partial product folds in exactly once"
    );

    println!("outputs (all verified against the integer reference ✓):");
    println!("  y = {outputs:?}\n");
    println!("AP execution summary:");
    println!(
        "  jobs          : {} ({} MACs + {} Reduces, {} fold rounds each)",
        metrics.jobs, OUTPUTS, OUTPUTS, rounds_per_neuron
    );
    println!("  row-ops       : {}", metrics.rows);
    println!("  rows moved    : {} (in-engine, between fold rounds)", metrics.reduce_rows_moved);
    println!("  modeled energy: {:.3e} J", total_energy);
    println!("  modeled delay : {} AP clock cycles", total_cycles);
    println!("  wall clock    : {:?} ({:.0} row-ops/s)", wall, metrics.rows as f64 / wall.as_secs_f64());

    // ---- the paper's headline comparisons, scaled to this workload ------
    // Each MAC/add row-op writes ~the same cost structure as the adder;
    // compare with (a) the equivalent binary AP doing the same digit work
    // and (b) a serial ternary CLA doing the additions.
    let cla = cla_model();
    let add_ops: u64 = metrics.rows;
    let cla_energy = cla.energy(add_ops as usize, ACC_TRITS);
    let cla_cycles = cla.delay_cycles(add_ops as usize, ACC_TRITS);
    println!("\nheadline comparisons (paper §VI):");
    println!(
        "  vs ternary CLA [15]: energy ×{:.2} lower ({:.3e} J vs {:.3e} J), delay ×{:.1} lower",
        cla_energy / total_energy,
        total_energy,
        cla_energy,
        cla_cycles / total_cycles as f64
    );
    println!(
        "  (paper anchors at 20t/512 rows: −52.64% energy, 9.5× delay vs CLA; \
         this workload uses 8-trit ops at {} parallel rows)",
        INPUTS
    );
    Ok(())
}
