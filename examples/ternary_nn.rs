//! End-to-end driver: a ternary neural-network layer computed **entirely
//! with AP operations**, on a real small workload.
//!
//! Workload: `y = W · x` for a 16×1024 ternary weight matrix and ternary
//! activations (the §I motivation: machine-learning kernels as massively
//! parallel digit-wise ops). The whole layer is **one compiled program**
//! ([`mvap::program::builtin::affine_layer`]) executed as a single engine
//! invocation:
//!
//!   1. one row-parallel MAC over all 16×1024 = 16384 `(W_ji, x_i)` rows,
//!      **fused** with
//!   2. one segmented tree reduction (a 1024-row segment per neuron): all
//!      16 dot products fold in lockstep over ⌈log₂ 1024⌉ = 10 pairwise
//!      rounds, with plane-native row movement between rounds, then
//!   3. the (zero) bias adds onto the 16 compacted sums in place.
//!
//! No partial product or partial sum EVER returns to the host — the
//! planner keeps every intermediate CAM-resident (asserted below via the
//! `resident_reuses` counter). The pre-program version of this example
//! paid a host round-trip between the MAC job and the Reduce job per
//! neuron (32 jobs; and the pre-Reduce version before it paid one per
//! pairing round — 10 Add jobs per neuron with host reshaping between
//! each). This one submits exactly ONE unit of work.
//!
//! The run verifies against an integer reference and reports the paper's
//! headline metrics (energy vs the binary AP, delay vs the ternary CLA).
//!
//! Run: `cargo run --release --example ternary_nn`
//!      (`-- --backend native-bitsliced` for the digit-plane storage;
//!       programs run on the native backends — PJRT artifacts cover
//!       element-wise ops only)

use mvap::baselines::cla_model;
use mvap::coordinator::{BackendKind, EngineService};
use mvap::mvl::{Radix, Word};
use mvap::program::{builtin, BoundProgram};
use mvap::util::cli::Args;
use mvap::util::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const INPUTS: usize = 1024;
const OUTPUTS: usize = 16;
/// Accumulator width: sums ≤ 1024·4 < 3^8.
const ACC_TRITS: usize = 8;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let backend: BackendKind = args
        .get_or("backend", "native")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    args.reject_unknown();
    if backend == BackendKind::Pjrt {
        anyhow::bail!(
            "program execution is native-only — use --backend native or native-bitsliced"
        );
    }

    let radix = Radix::TERNARY;
    let mut rng = Rng::new(1234);
    // synthetic ternary layer: weights and activations ∈ {0, 1, 2}
    let weights: Vec<Vec<u8>> = (0..OUTPUTS).map(|_| rng.number(INPUTS, 3)).collect();
    let x: Vec<u8> = rng.number(INPUTS, 3);

    let svc = EngineService::start_kind(2, 4, backend, artifacts)?;
    println!(
        "ternary NN layer: {OUTPUTS} neurons × {INPUTS} inputs as ONE program on the {} backend\n",
        match backend {
            BackendKind::Pjrt => unreachable!(),
            BackendKind::Native => "native simulator",
            BackendKind::NativeBitSliced => "native simulator (bit-sliced digit planes)",
        }
    );

    // ---- compile the layer: mac ⊕ segmented-reduce ⊕ bias-add ----------
    let program = builtin::affine_layer(radix, ACC_TRITS, INPUTS);
    let plan = Arc::new(program.plan());
    print!("{}", plan.render());
    println!();

    // ---- bind the operands: W flattened, x tiled per neuron, zero bias -
    let as_word = |v: u8| Word::from_u128(v as u128, ACC_TRITS, radix);
    let w_rows: Vec<Word> = weights.iter().flatten().map(|&w| as_word(w)).collect();
    let x_rows: Vec<Word> = (0..OUTPUTS).flat_map(|_| x.iter().map(|&v| as_word(v))).collect();
    let bias: Vec<Word> = (0..OUTPUTS).map(|_| as_word(0)).collect();
    let bound = BoundProgram::bind(
        &plan,
        vec![("w", w_rows), ("x", x_rows), ("bias", bias)],
        true,
    )?;

    // ---- ONE engine invocation for the whole layer ---------------------
    let started = std::time::Instant::now();
    let report = svc.run_program(bound)?;
    let wall = started.elapsed();
    let metrics = svc.shutdown();

    // verify against the integer reference
    let outputs: Vec<u64> = report.outputs[0].iter().map(|w| w.to_u128() as u64).collect();
    for (j, w_row) in weights.iter().enumerate() {
        let expect: u64 = w_row.iter().zip(&x).map(|(&w, &xi)| w as u64 * xi as u64).sum();
        assert_eq!(outputs[j], expect, "neuron {j}");
    }

    // exactly one program; the MAC fused into the reduction; both
    // intermediates (products, sums) consumed CAM-resident — zero host
    // round-trips between the MAC and the Reduce
    assert_eq!(metrics.programs, 1);
    assert_eq!(metrics.jobs, 1, "the whole layer is one unit of work");
    assert_eq!(metrics.fused_steps, 1);
    assert_eq!(
        metrics.resident_reuses, 2,
        "reduce consumes the products in place, the bias add consumes the sums"
    );
    let rounds_per_layer = mvap::ap::fold_rounds(INPUTS) as u64; // 10, lockstep
    assert_eq!(metrics.reduce_rounds, rounds_per_layer);
    assert_eq!(
        metrics.reduce_rows_moved,
        (OUTPUTS * (INPUTS - 1) + (OUTPUTS - 1)) as u64,
        "every partial product folds in exactly once; 15 segment heads compact"
    );

    println!("outputs (all verified against the integer reference ✓):");
    println!("  y = {outputs:?}\n");
    println!("AP execution summary:");
    print!("{}", report.render());
    println!(
        "  fold rounds   : {} (all {OUTPUTS} neurons in lockstep)",
        metrics.reduce_rounds
    );
    println!("  rows moved    : {} (in-engine, between fold rounds)", metrics.reduce_rows_moved);
    println!("  row-ops       : {}", metrics.rows);
    println!(
        "  wall clock    : {:?} ({:.0} row-ops/s)",
        wall,
        metrics.rows as f64 / wall.as_secs_f64()
    );

    // ---- the paper's headline comparisons, scaled to this workload ------
    // Each MAC/add row-op writes ~the same cost structure as the adder;
    // compare with a serial ternary CLA doing the additions.
    let total_energy = report.energy.total();
    let total_cycles = report.delay_cycles;
    let cla = cla_model();
    let add_ops: u64 = metrics.rows;
    let cla_energy = cla.energy(add_ops as usize, ACC_TRITS);
    let cla_cycles = cla.delay_cycles(add_ops as usize, ACC_TRITS);
    println!("\nheadline comparisons (paper §VI):");
    println!(
        "  vs ternary CLA [15]: energy ×{:.2} lower ({:.3e} J vs {:.3e} J), delay ×{:.1} lower",
        cla_energy / total_energy,
        total_energy,
        cla_energy,
        cla_cycles / total_cycles as f64
    );
    println!(
        "  (paper anchors at 20t/512 rows: −52.64% energy, 9.5× delay vs CLA; \
         this workload uses 8-trit ops at {} parallel rows)",
        OUTPUTS * INPUTS
    );
    Ok(())
}
