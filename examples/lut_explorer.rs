//! LUT explorer: the "universal methodology" claim of §I exercised over a
//! zoo of arithmetic/logic functions and radices 2–5: build the state
//! diagram, break cycles, generate both LUT flavours, validate soundness,
//! and summarise pass/block counts (the AP "program size" of each op).
//!
//! Run: `cargo run --release --example lut_explorer [-- --dot]`

use mvap::diagram::{dot, StateDiagram};
use mvap::func::{full_add, full_sub, half_add, logic2, mac_digit, Logic2, TruthTable};
use mvap::lutgen::{generate_blocked, generate_non_blocked, validate_lut};
use mvap::mvl::Radix;
use mvap::util::cli::Args;
use mvap::util::Table;

fn zoo(radix: Radix) -> Vec<TruthTable> {
    vec![
        full_add(radix),
        full_sub(radix),
        half_add(radix),
        mac_digit(radix),
        logic2(Logic2::And, radix),
        logic2(Logic2::Or, radix),
        logic2(Logic2::Nor, radix),
        logic2(Logic2::Xor, radix),
        logic2(Logic2::AbsDiff, radix),
    ]
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut t = Table::new("LUT program sizes across the function zoo").header(&[
        "function",
        "radix",
        "states",
        "noAction",
        "passes",
        "blocks",
        "cycle rewrites",
        "sound",
    ]);
    for n in 2..=5u8 {
        let radix = Radix(n);
        for table in zoo(radix) {
            let name = table.name().to_string();
            let d = match StateDiagram::build(table) {
                Ok(d) => d,
                Err(e) => {
                    println!("{name}: not implementable in-place ({e})");
                    continue;
                }
            };
            let nb = generate_non_blocked(&d);
            let b = generate_blocked(&d);
            let sound = validate_lut(&nb, d.table()).is_empty()
                && validate_lut(&b, d.table()).is_empty();
            t.row(&[
                name,
                n.to_string(),
                d.nodes().len().to_string(),
                d.roots().len().to_string(),
                nb.passes.len().to_string(),
                b.num_groups.to_string(),
                d.rewrites().len().to_string(),
                if sound { "✓".into() } else { "✗".to_string() },
            ]);
        }
    }
    t.print();
    println!(
        "\nblocks < passes is the blocked approach's delay win: write cycles \
         shrink from `passes` to `blocks` per digit (§V)."
    );

    if args.flag("dot") {
        println!("\n// Fig. 5 equivalent (pipe into `dot -Tsvg`):");
        let d = StateDiagram::build(full_add(Radix::TERNARY))?;
        print!("{}", dot::to_dot(&d));
    }
    Ok(())
}
