#!/usr/bin/env python3
"""Fail-loud perf-regression gate over the quick-bench trajectory files.

Usage:
    python3 tools/perf_gate.py BENCH_8.json BENCH_10.json [more ...]

The first file is the PR-8 trajectory of record (`hot/parallel_apply_*`
plus the arena and PR-3 benches); the second is the PR-10 telemetry
trajectory (`hot/trace_*`); any further files are only checked for
non-emptiness. Five checks:

  (a) every listed trajectory file must exist and hold at least one
      result record — an empty trajectory means the bench stage silently
      recorded nothing, which is exactly the failure this gate exists
      to catch;
  (b) the 4-thread bit-sliced kernel application at 256k rows must be at
      least MIN_SPEEDUP_4T x faster (p50 wall-clock) than the 1-thread
      run — skipped with a loud warning when the machine itself has
      fewer than 4 CPUs, since no scheduler can conjure missing cores;
  (c) the 1-thread run must not be more than MAX_1T_OVERHEAD slower than
      the plain sequential constructor at 256k rows — the parallel knob
      at threads=1 takes the identical code path (word_cuts never
      partitions), so any gap beyond noise is dispatch overhead leaking
      into the default configuration;
  (d) an attached-but-disarmed tracer (the not-sampled request path —
      one branch per span site) must cost at most MAX_TRACE_DISARMED
      over the tracing-disabled execute at 256k rows: the PR-10
      zero-cost-when-off contract, measured, not asserted;
  (e) an armed tracer (every span recorded into the per-thread ring)
      must cost at most MAX_TRACE_ARMED over disabled — spans are per
      tile/step, never per row, so overhead must not scale with rows.

Exit status 0 = gate passed; 1 = regression (or empty trajectory).
"""

import json
import os
import sys

GATE_ROWS = 262_144
SEQ_BENCH = f"hot/parallel_apply_seq_{GATE_ROWS}rows"
ONE_T_BENCH = f"hot/parallel_apply_1t_{GATE_ROWS}rows"
FOUR_T_BENCH = f"hot/parallel_apply_4t_{GATE_ROWS}rows"
MIN_SPEEDUP_4T = 2.0
MAX_1T_OVERHEAD = 1.10
TRACE_OFF_BENCH = f"hot/trace_off_{GATE_ROWS}rows"
TRACE_DISARMED_BENCH = f"hot/trace_unsampled_{GATE_ROWS}rows"
TRACE_ARMED_BENCH = f"hot/trace_sampled_{GATE_ROWS}rows"
MAX_TRACE_DISARMED = 1.02
MAX_TRACE_ARMED = 1.10


def fail(msg):
    print(f"PERF GATE FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def load_results(path):
    """Return {bench name: p50 ns} for one trajectory file, or fail.

    Missing file and empty trajectory are distinct failures: a missing
    file means the bench stage (or the repo) never produced the
    trajectory at all — check the ci.sh --json invocation and that the
    placeholder is committed; an empty results array means the stage ran
    but recorded nothing (wrong bench filter, or an uncommitted
    placeholder was never populated by a CI run)."""
    if not os.path.exists(path):
        fail(
            f"trajectory file {path} does not exist — the bench stage never "
            f"wrote it (check the ci.sh --json path and the committed placeholder)"
        )
    with open(path) as fh:
        try:
            doc = json.load(fh)
        except json.JSONDecodeError as e:
            fail(f"trajectory file {path} is not valid JSON: {e}")
    results = doc.get("results", [])
    if not results:
        if "note" in doc:
            fail(
                f"trajectory file {path} is an unpopulated placeholder "
                f"(empty results array with an authoring note) — run ./ci.sh "
                f"so the quick-bench stage records real results"
            )
        fail(
            f"trajectory file {path} holds zero results — the bench stage "
            f"ran but recorded nothing (check its bench-name filters)"
        )
    by_name = {}
    for rec in results:
        if "name" not in rec or "p50_ns" not in rec:
            fail(f"malformed record in {path}: {rec!r}")
        by_name[rec["name"]] = float(rec["p50_ns"])
    return by_name


def check_trace_overhead(path):
    """(d)+(e): the telemetry overhead gates over the PR-10 trajectory."""
    p50 = load_results(path)
    for name in (TRACE_OFF_BENCH, TRACE_DISARMED_BENCH, TRACE_ARMED_BENCH):
        if name not in p50:
            fail(f"{path} is missing the gated bench {name}")
    off = p50[TRACE_OFF_BENCH]
    disarmed = p50[TRACE_DISARMED_BENCH]
    armed = p50[TRACE_ARMED_BENCH]
    if min(off, disarmed, armed) <= 0:
        fail(
            f"non-positive p50 in trace benches: off={off} "
            f"disarmed={disarmed} armed={armed}"
        )
    for label, got, limit in (
        ("disarmed tracer", disarmed / off, MAX_TRACE_DISARMED),
        ("armed tracer", armed / off, MAX_TRACE_ARMED),
    ):
        print(
            f"perf gate: {label} overhead at {GATE_ROWS} rows: "
            f"{got:.3f}x disabled (limit {limit:.2f}x)"
        )
        if got > limit:
            fail(
                f"{label} p50 is {got:.3f}x the tracing-disabled p50 "
                f"({off:.0f} ns) at {GATE_ROWS} rows — limit is {limit:.2f}x; "
                f"the zero-cost-when-off contract is broken"
            )


def main(argv):
    if len(argv) < 3:
        fail("usage: perf_gate.py BENCH_8.json BENCH_10.json [more trajectories ...]")

    gate_path = argv[1]
    p50 = load_results(gate_path)
    check_trace_overhead(argv[2])
    for extra in argv[3:]:
        load_results(extra)  # (a) non-emptiness only

    for name in (SEQ_BENCH, ONE_T_BENCH, FOUR_T_BENCH):
        if name not in p50:
            fail(f"{gate_path} is missing the gated bench {name}")

    seq, one_t, four_t = p50[SEQ_BENCH], p50[ONE_T_BENCH], p50[FOUR_T_BENCH]
    if min(seq, one_t, four_t) <= 0:
        fail(f"non-positive p50 in gated benches: seq={seq} 1t={one_t} 4t={four_t}")

    # (c) threads=1 must stay within noise of the sequential path.
    overhead = one_t / seq
    print(
        f"perf gate: 1-thread overhead at {GATE_ROWS} rows: "
        f"{overhead:.3f}x sequential (limit {MAX_1T_OVERHEAD:.2f}x)"
    )
    if overhead > MAX_1T_OVERHEAD:
        fail(
            f"1-thread p50 ({one_t:.0f} ns) is {overhead:.2f}x the sequential "
            f"p50 ({seq:.0f} ns) at {GATE_ROWS} rows — limit is "
            f"{MAX_1T_OVERHEAD:.2f}x; the parallel knob is taxing the default path"
        )

    # (b) 4 threads must actually buy parallel speedup.
    cpus = os.cpu_count() or 1
    speedup = one_t / four_t
    if cpus < 4:
        print(
            f"perf gate: WARNING — only {cpus} CPU(s) visible; skipping the "
            f">= {MIN_SPEEDUP_4T:.1f}x 4-thread speedup check (measured "
            f"{speedup:.2f}x). Run on a >= 4-core machine to enforce it.",
            file=sys.stderr,
        )
    else:
        print(
            f"perf gate: 4-thread speedup at {GATE_ROWS} rows: {speedup:.2f}x "
            f"over 1 thread (required >= {MIN_SPEEDUP_4T:.1f}x, {cpus} CPUs)"
        )
        if speedup < MIN_SPEEDUP_4T:
            fail(
                f"4-thread p50 ({four_t:.0f} ns) is only {speedup:.2f}x faster "
                f"than 1-thread ({one_t:.0f} ns) at {GATE_ROWS} rows — "
                f"required >= {MIN_SPEEDUP_4T:.1f}x on a {cpus}-CPU machine"
            )

    print("perf gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
