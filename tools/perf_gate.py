#!/usr/bin/env python3
"""Fail-loud perf-regression gate over the quick-bench trajectory files.

Usage:
    python3 tools/perf_gate.py BENCH_8.json [more BENCH_*.json ...]

The first file is the PR-8 trajectory of record (`hot/parallel_apply_*`
plus the arena and PR-3 benches); any further files are only checked for
non-emptiness. Three checks, mirrored from ISSUE 8:

  (a) every listed trajectory file must exist and hold at least one
      result record — an empty trajectory means the bench stage silently
      recorded nothing, which is exactly the failure this gate exists
      to catch;
  (b) the 4-thread bit-sliced kernel application at 256k rows must be at
      least MIN_SPEEDUP_4T x faster (p50 wall-clock) than the 1-thread
      run — skipped with a loud warning when the machine itself has
      fewer than 4 CPUs, since no scheduler can conjure missing cores;
  (c) the 1-thread run must not be more than MAX_1T_OVERHEAD slower than
      the plain sequential constructor at 256k rows — the parallel knob
      at threads=1 takes the identical code path (word_cuts never
      partitions), so any gap beyond noise is dispatch overhead leaking
      into the default configuration.

Exit status 0 = gate passed; 1 = regression (or empty trajectory).
"""

import json
import os
import sys

GATE_ROWS = 262_144
SEQ_BENCH = f"hot/parallel_apply_seq_{GATE_ROWS}rows"
ONE_T_BENCH = f"hot/parallel_apply_1t_{GATE_ROWS}rows"
FOUR_T_BENCH = f"hot/parallel_apply_4t_{GATE_ROWS}rows"
MIN_SPEEDUP_4T = 2.0
MAX_1T_OVERHEAD = 1.10


def fail(msg):
    print(f"PERF GATE FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def load_results(path):
    """Return {bench name: p50 ns} for one trajectory file, or fail.

    Missing file and empty trajectory are distinct failures: a missing
    file means the bench stage (or the repo) never produced the
    trajectory at all — check the ci.sh --json invocation and that the
    placeholder is committed; an empty results array means the stage ran
    but recorded nothing (wrong bench filter, or an uncommitted
    placeholder was never populated by a CI run)."""
    if not os.path.exists(path):
        fail(
            f"trajectory file {path} does not exist — the bench stage never "
            f"wrote it (check the ci.sh --json path and the committed placeholder)"
        )
    with open(path) as fh:
        try:
            doc = json.load(fh)
        except json.JSONDecodeError as e:
            fail(f"trajectory file {path} is not valid JSON: {e}")
    results = doc.get("results", [])
    if not results:
        if "note" in doc:
            fail(
                f"trajectory file {path} is an unpopulated placeholder "
                f"(empty results array with an authoring note) — run ./ci.sh "
                f"so the quick-bench stage records real results"
            )
        fail(
            f"trajectory file {path} holds zero results — the bench stage "
            f"ran but recorded nothing (check its bench-name filters)"
        )
    by_name = {}
    for rec in results:
        if "name" not in rec or "p50_ns" not in rec:
            fail(f"malformed record in {path}: {rec!r}")
        by_name[rec["name"]] = float(rec["p50_ns"])
    return by_name


def main(argv):
    if len(argv) < 2:
        fail("usage: perf_gate.py BENCH_8.json [more trajectories ...]")

    gate_path = argv[1]
    p50 = load_results(gate_path)
    for extra in argv[2:]:
        load_results(extra)  # (a) non-emptiness only

    for name in (SEQ_BENCH, ONE_T_BENCH, FOUR_T_BENCH):
        if name not in p50:
            fail(f"{gate_path} is missing the gated bench {name}")

    seq, one_t, four_t = p50[SEQ_BENCH], p50[ONE_T_BENCH], p50[FOUR_T_BENCH]
    if min(seq, one_t, four_t) <= 0:
        fail(f"non-positive p50 in gated benches: seq={seq} 1t={one_t} 4t={four_t}")

    # (c) threads=1 must stay within noise of the sequential path.
    overhead = one_t / seq
    print(
        f"perf gate: 1-thread overhead at {GATE_ROWS} rows: "
        f"{overhead:.3f}x sequential (limit {MAX_1T_OVERHEAD:.2f}x)"
    )
    if overhead > MAX_1T_OVERHEAD:
        fail(
            f"1-thread p50 ({one_t:.0f} ns) is {overhead:.2f}x the sequential "
            f"p50 ({seq:.0f} ns) at {GATE_ROWS} rows — limit is "
            f"{MAX_1T_OVERHEAD:.2f}x; the parallel knob is taxing the default path"
        )

    # (b) 4 threads must actually buy parallel speedup.
    cpus = os.cpu_count() or 1
    speedup = one_t / four_t
    if cpus < 4:
        print(
            f"perf gate: WARNING — only {cpus} CPU(s) visible; skipping the "
            f">= {MIN_SPEEDUP_4T:.1f}x 4-thread speedup check (measured "
            f"{speedup:.2f}x). Run on a >= 4-core machine to enforce it.",
            file=sys.stderr,
        )
    else:
        print(
            f"perf gate: 4-thread speedup at {GATE_ROWS} rows: {speedup:.2f}x "
            f"over 1 thread (required >= {MIN_SPEEDUP_4T:.1f}x, {cpus} CPUs)"
        )
        if speedup < MIN_SPEEDUP_4T:
            fail(
                f"4-thread p50 ({four_t:.0f} ns) is only {speedup:.2f}x faster "
                f"than 1-thread ({one_t:.0f} ns) at {GATE_ROWS} rows — "
                f"required >= {MIN_SPEEDUP_4T:.1f}x on a {cpus}-CPU machine"
            )

    print("perf gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
