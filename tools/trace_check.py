#!/usr/bin/env python3
"""Structural checker for `mvap`'s Chrome trace-event JSON exports.

Usage:
    python3 tools/trace_check.py TRACE.json [options]

Options:
    --allow-drops       tolerate droppedSpans > 0 (the deep per-request
                        checks are skipped in that case, loudly — a
                        partial trace cannot prove chain completeness)
    --require-complete  every flow finish must have a matching start
                        (front-door traces only: `mvap serve --trace` and
                        `mvap trace` open a flow at the admit edge;
                        `mvap run --trace` has no edge, so its replies
                        legitimately finish flows nobody started)
    --require-steal     at least one reply span must be marked stolen
    --require-coalesce  at least one flush span must carry >= 2 jobs

Checks, in order:

  1. the file parses, `traceEvents` is non-empty, and the `otherData`
     envelope carries the sample rate and dropped-span counter;
  2. sync `B`/`E` events balance per (pid, tid) lane in file order —
     every `E` closes the innermost open `B` at a timestamp no earlier
     than it opened, and no lane ends with an open span;
  3. async `b`/`e` pairs (the per-job attribution spans) balance per
     (category, id);
  4. each flow id has at most one start and one finish; a start without
     a finish is always fatal (an admitted request whose causal chain
     never reached a reply); start precedes finish; the start lies
     inside an `admit` span and the finish inside a `reply` span on
     their respective lanes;
  5. when the trace kept everything (sample == 1, zero drops) and
     aggregate metrics snapshots are attached, the modeled energy on the
     job/program spans must reconcile with `modeledEnergyJ` to within
     1e-9 relative — the spans and the metrics are two independent
     accountings of the same physics model, so daylight between them
     means an instrumentation bug.

Exit status 0 = trace is well-formed; 1 = any check failed.
"""

import json
import sys

ENERGY_REL_TOL = 1e-9


class TraceError(Exception):
    pass


def fail(msg):
    raise TraceError(msg)


def load(path):
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents is missing or empty")
    other = doc.get("otherData", {})
    if "sample" not in other or "droppedSpans" not in other:
        fail(f"{path}: otherData lacks sample/droppedSpans — not an mvap trace")
    return doc


def check_sync_stacks(events):
    """B/E discipline per lane, in file order. Returns the closed
    intervals as {(pid, tid): [(name, start_ts, end_ts), ...]}."""
    stacks = {}  # lane -> [(name, ts)]
    last_ts = {}  # lane -> most recent B/E timestamp
    intervals = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("B", "E"):
            continue
        lane = (ev.get("pid"), ev.get("tid"))
        ts = float(ev["ts"])
        if lane in last_ts and ts < last_ts[lane]:
            fail(
                f"event {i}: lane {lane} timestamp regressed "
                f"({ts} after {last_ts[lane]})"
            )
        last_ts[lane] = ts
        if ph == "B":
            if "name" not in ev:
                fail(f"event {i}: B without a name on lane {lane}")
            stacks.setdefault(lane, []).append((ev["name"], ts))
        else:
            stack = stacks.get(lane, [])
            if not stack:
                fail(f"event {i}: E with no open span on lane {lane}")
            name, begin = stack.pop()
            if ts < begin:
                fail(
                    f"event {i}: span '{name}' on lane {lane} closes at "
                    f"{ts} before it opened at {begin}"
                )
            intervals.setdefault(lane, []).append((name, begin, ts))
    for lane, stack in stacks.items():
        if stack:
            open_names = [n for n, _ in stack]
            fail(f"lane {lane} ends with unclosed spans: {open_names}")
    return intervals


def check_async_pairs(events):
    """b/e balance per (cat, id) — the per-job attribution spans."""
    open_by_key = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("b", "e"):
            continue
        key = (ev.get("cat"), ev.get("id"))
        if key[1] is None:
            fail(f"event {i}: async {ph} without an id")
        ts = float(ev["ts"])
        if ph == "b":
            open_by_key.setdefault(key, []).append(ts)
        else:
            stack = open_by_key.get(key, [])
            if not stack:
                fail(f"event {i}: async e with no open b for {key}")
            begin = stack.pop()
            if ts < begin:
                fail(f"event {i}: async span {key} ends at {ts} before {begin}")
    for key, stack in open_by_key.items():
        if stack:
            fail(f"async span {key} never closed ({len(stack)} open)")


def enclosed_by(intervals, lane, ts, name):
    return any(
        n == name and begin <= ts <= end
        for n, begin, end in intervals.get(lane, [])
    )


def check_flows(events, intervals, require_complete):
    """Each flow id: one start inside an admit span, one finish inside a
    reply span, start before finish. Returns the number of complete
    (start + finish) chains."""
    starts, finishes = {}, {}
    for i, ev in enumerate(events):
        if ev.get("cat") != "flow":
            continue
        ph, fid = ev.get("ph"), ev.get("id")
        lane = (ev.get("pid"), ev.get("tid"))
        ts = float(ev["ts"])
        side = {"s": starts, "f": finishes}.get(ph)
        if side is None:
            fail(f"event {i}: unexpected flow phase '{ph}'")
        if fid in side:
            fail(f"event {i}: duplicate flow {ph} for id {fid}")
        side[fid] = (ts, lane)
    complete = 0
    for fid, (ts, lane) in starts.items():
        if not enclosed_by(intervals, lane, ts, "admit"):
            fail(f"flow {fid}: start at {ts} is not inside an admit span on {lane}")
        if fid not in finishes:
            fail(
                f"flow {fid}: started (request admitted) but never finished — "
                f"its causal chain never reached a reply"
            )
        fts, flane = finishes[fid]
        if fts < ts:
            fail(f"flow {fid}: finishes at {fts} before it starts at {ts}")
        complete += 1
    for fid, (ts, lane) in finishes.items():
        if not enclosed_by(intervals, lane, ts, "reply"):
            fail(f"flow {fid}: finish at {ts} is not inside a reply span on {lane}")
        if fid not in starts and require_complete:
            fail(
                f"flow {fid}: finished but never started — the admit edge "
                f"span is missing (--require-complete)"
            )
    return complete


def span_energy_j(events):
    """Sum the one energy-bearing span per request: async job `b` events
    plus sync program `B` events (program steps subdivide their program's
    energy and must NOT be double-counted)."""
    total = 0.0
    for ev in events:
        args = ev.get("args", {})
        if "energyJ" not in args:
            continue
        if ev.get("ph") == "b" and ev.get("cat") == "req":
            total += float(args["energyJ"])
        elif ev.get("ph") == "B" and ev.get("name") == "program":
            total += float(args["energyJ"])
    return total


def check_energy(doc):
    aggregates = [
        s for s in doc.get("metricsSnapshots", []) if s.get("scope") == "aggregate"
    ]
    if not aggregates:
        print("trace check: no aggregate snapshots — energy reconciliation skipped")
        return
    metered = sum(float(s.get("modeledEnergyJ", 0.0)) for s in aggregates)
    spanned = span_energy_j(doc["traceEvents"])
    scale = max(abs(metered), abs(spanned), 1e-30)
    rel = abs(metered - spanned) / scale
    if rel > ENERGY_REL_TOL:
        fail(
            f"span energy {spanned:.17e} J does not reconcile with the "
            f"metrics' modeledEnergyJ {metered:.17e} J "
            f"(relative error {rel:.3e} > {ENERGY_REL_TOL:.0e})"
        )
    print(
        f"trace check: energy reconciles — spans {spanned:.6e} J vs "
        f"metrics {metered:.6e} J (rel {rel:.2e})"
    )


def check_requirements(events, require_steal, require_coalesce):
    if require_steal:
        stolen = any(
            ev.get("ph") == "B"
            and ev.get("name") == "reply"
            and ev.get("args", {}).get("stolen") is True
            for ev in events
        )
        if not stolen:
            fail("--require-steal: no reply span is marked stolen")
    if require_coalesce:
        coalesced = any(
            ev.get("ph") == "B"
            and ev.get("name") == "flush"
            and int(ev.get("args", {}).get("jobs", 0)) >= 2
            for ev in events
        )
        if not coalesced:
            fail("--require-coalesce: no flush span carries >= 2 jobs")


def check(path, allow_drops=False, require_complete=False, require_steal=False,
          require_coalesce=False):
    doc = load(path)
    events = doc["traceEvents"]
    other = doc["otherData"]
    dropped = int(other["droppedSpans"])
    sample = int(other["sample"])

    if dropped > 0 and not allow_drops:
        fail(
            f"{dropped} spans were dropped from the ring buffers — "
            f"raise the sink capacity or sample rate, or pass --allow-drops"
        )

    intervals = check_sync_stacks(events)
    check_async_pairs(events)

    if dropped > 0:
        print(
            f"trace check: WARNING — {dropped} dropped spans; flow-chain and "
            f"energy checks skipped (a partial trace cannot prove them)",
            file=sys.stderr,
        )
    else:
        chains = check_flows(events, intervals, require_complete)
        print(f"trace check: {chains} complete admit->reply flow chains")
        if sample <= 1:
            check_energy(doc)
        else:
            print(
                f"trace check: sample 1/{sample} — energy reconciliation "
                f"skipped (unsampled requests carry energy but no spans)"
            )

    check_requirements(events, require_steal, require_coalesce)
    n_sync = sum(1 for e in events if e.get("ph") == "B")
    print(f"trace check passed: {path} ({len(events)} events, {n_sync} sync spans)")


def main(argv):
    flags = {a for a in argv[1:] if a.startswith("--")}
    paths = [a for a in argv[1:] if not a.startswith("--")]
    known = {"--allow-drops", "--require-complete", "--require-steal",
             "--require-coalesce"}
    unknown = flags - known
    if unknown or len(paths) != 1:
        print(
            f"usage: trace_check.py TRACE.json [--allow-drops] "
            f"[--require-complete] [--require-steal] [--require-coalesce]"
            + (f"\nunknown flags: {sorted(unknown)}" if unknown else ""),
            file=sys.stderr,
        )
        return 2
    try:
        check(
            paths[0],
            allow_drops="--allow-drops" in flags,
            require_complete="--require-complete" in flags,
            require_steal="--require-steal" in flags,
            require_coalesce="--require-coalesce" in flags,
        )
    except TraceError as e:
        print(f"TRACE CHECK FAILED: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
